"""Serving example: batched generation with the sort-scheduled engine.

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import ServeEngine, Request

cfg = get_smoke_config("internlm2_1_8b")
params = init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, batch_size=4, max_len=128)

rng = np.random.default_rng(0)
queue = [Request(rid=i,
                 prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 16))),
                 max_new_tokens=int(rng.integers(8, 32)))
         for i in range(10)]

batches = engine.schedule(queue)     # counting-pass over remaining-length class
print(f"{len(queue)} requests -> {len(batches)} batches "
      f"(sorted by remaining-length class to cut straggler idle)")
for b, reqs in enumerate(batches):
    done = engine.generate(reqs)
    for r in done:
        print(f"  batch {b} req {r.rid}: prompt_len={len(r.prompt)} "
              f"generated={len(r.generated)} tokens, first5={r.generated[:5]}")
