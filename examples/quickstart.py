"""Quickstart: the hybrid radix sort public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (hybrid_sort, lsd_sort, SortConfig, default_config,
                        memory_budget, expected_speedup)

rng = np.random.default_rng(0)

# --- sort keys of any primitive dtype --------------------------------------
keys = jnp.asarray(rng.integers(0, 2**32, 1 << 18, dtype=np.uint32))
out, stats = hybrid_sort(keys, return_stats=True)
print(f"u32 uniform: sorted={bool((out[1:] >= out[:-1]).all())} "
      f"counting_passes={int(stats.counting_passes)} (of 4 worst-case) "
      f"local_sort={bool(stats.used_local_sort)}")

floats = jnp.asarray(rng.standard_normal(100_000).astype(np.float32))
print("f32:", bool((hybrid_sort(floats)[1:] >= hybrid_sort(floats)[:-1]).all()))

# --- key-value pairs (decomposed layout, §4.6) ------------------------------
vals = jnp.arange(keys.shape[0], dtype=jnp.int32)
sk, sv = hybrid_sort(keys, vals)
print("pairs move together:", bool((keys[sv] == sk).all()))

# --- skewed distributions: the MSD design is what keeps this fast -----------
skewed = jnp.asarray(rng.integers(0, 2**32, 1 << 18, dtype=np.uint32)
                     & rng.integers(0, 2**32, 1 << 18, dtype=np.uint32))
_, st2 = hybrid_sort(skewed, return_stats=True)
print(f"skewed: passes={int(st2.counting_passes)}")

# --- the CUB-style LSD baseline the paper compares against ------------------
assert bool((lsd_sort(keys, d=5) == out).all())
print("lsd(d=5) agrees with hybrid")

# --- the paper's analytical model (§4.5) -----------------------------------
cfg = default_config(4)
b = memory_budget(500_000_000, 32, cfg)
print(f"aux memory for 2GB of u32: {b['aux_over_m1']*100:.1f}% of input "
      f"(paper: <5%); expected speedup vs LSD-5: {expected_speedup(32):.2f}x")
