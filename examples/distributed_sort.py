import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Distributed sort example (paper §5 on a TPU mesh): 8 chips sort 2M keys.

Shows the full pipeline — local hybrid sort, sampled splitters, capacity-
padded all_to_all, multiway merge — including the pipelined (chunked) variant.

    PYTHONPATH=src python examples/distributed_sort.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distributed import make_distributed_sort, valid_concat

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
n = 1 << 21

for name, ands, chunks in (("uniform s=1", 0, 1), ("skewed s=1", 3, 1),
                           ("uniform s=4 (pipelined)", 0, 4)):
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    for _ in range(ands):
        x &= rng.integers(0, 2**32, n, dtype=np.uint32)
    fn = jax.jit(make_distributed_sort(mesh, "data", num_chunks=chunks))
    out, stats = fn(jnp.asarray(x))
    got = valid_concat(out, stats.valid)
    valid = np.asarray(stats.valid)
    ok = np.array_equal(np.sort(x), got)
    print(f"{name:24s} n={n} ok={ok} "
          f"attempts={int(np.asarray(stats.exchange_attempts)[0])} "
          f"overflow={bool(np.asarray(stats.overflow).any())} "
          f"shard fill={valid.mean() * 8 / np.asarray(out).shape[0]:.2f}")

# payloads ride the exchange: sort (key, doc-id) pairs
x = rng.integers(0, 2**32, n, dtype=np.uint32)
ids = np.arange(n, dtype=np.int32)
fn = jax.jit(make_distributed_sort(mesh, "data"))
out, out_ids, stats = fn(jnp.asarray(x), jnp.asarray(ids))
gk = valid_concat(out, stats.valid)
gi = valid_concat(out_ids, stats.valid)
print(f"{'kv pairs':24s} n={n} ok={np.array_equal(x[gi], gk)} "
      f"perm ok={np.array_equal(np.sort(gi), ids)}")
