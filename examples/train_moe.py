"""End-to-end training driver: a ~100M-param qwen3-family MoE with the
paper's sort-based expert dispatch, trained for a few hundred steps with
checkpointing (resume works: re-run the same command after killing it).

    PYTHONPATH=src python examples/train_moe.py --steps 200
    PYTHONPATH=src python examples/train_moe.py --steps 200 --small   # CI-sized
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.train import Trainer


def make_cfg(small: bool):
    base = get_config("qwen3_moe_30b_a3b")        # same family, scaled down
    if small:
        return dataclasses.replace(
            base, name="qwen3-moe-micro", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2, head_dim=32, d_ff=256, vocab=1024,
            num_experts=8, top_k=2, dtype="float32", vocab_pad_multiple=16)
    return dataclasses.replace(
        base, name="qwen3-moe-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=512, vocab=32000,
        num_experts=16, top_k=4, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--ckpt", default="checkpoints/train_moe")
    args = ap.parse_args()

    cfg = make_cfg(args.small)
    seq = args.seq_len or (64 if args.small else 256)
    batch = args.batch or (4 if args.small else 8)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    tr = Trainer(cfg, data, args.ckpt, ckpt_every=50, log_every=10,
                 base_lr=1e-3, total_steps=args.steps)
    state = tr.init_or_resume(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[example] {cfg.name}: {n/1e6:.1f}M params, "
          f"{cfg.num_experts} experts top-{cfg.top_k}, sort-based dispatch")
    tr.run(state, args.steps - int(state.step))


if __name__ == "__main__":
    main()
