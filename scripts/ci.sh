#!/usr/bin/env bash
# CI entry point: tier-1 pytest + an interpret-mode benchmark smoke pass.
#
# Everything runs on a plain CPU host: the Pallas kernels execute in
# interpret mode (the drivers default to it off-TPU), so the fused-engine
# parity and launch-count gates are exercised on every push without TPU
# hardware.  Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "=== tier-1 tests ==="
python -m pytest -q "$@"

echo "=== benchmark smoke (interpret mode) ==="
python -m benchmarks.run --json BENCH_smoke.json --smoke

echo "=== smoke bench notes ==="
python - <<'EOF'
import json
rows = json.load(open("BENCH_smoke.json"))
for note in rows.get("notes", []):
    print("WARNING:", note)
print("smoke rows:", sum(1 for k in rows if k != "notes"))
EOF
