#!/usr/bin/env bash
# CI entry point: tier-1 pytest + an interpret-mode benchmark smoke pass.
#
# Everything runs on a plain CPU host: the Pallas kernels execute in
# interpret mode (the drivers default to it off-TPU), so the fused-engine
# parity and launch-count gates are exercised on every push without TPU
# hardware.  Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "=== tier-1 tests ==="
python -m pytest -q "$@"

echo "=== benchmark smoke (interpret mode, engine + out-of-core sweeps) ==="
python -m benchmarks.run --json BENCH_smoke.json --smoke --ooc

echo "=== smoke bench notes ==="
python - <<'EOF'
import json
for path in ("BENCH_smoke.json", "BENCH_ooc.json"):
    rows = json.load(open(path))
    for note in rows.get("notes", []):
        print(f"WARNING [{path}]:", note)
    print(f"{path} rows:", sum(1 for k in rows if k != "notes"))
EOF
