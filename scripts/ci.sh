#!/usr/bin/env bash
# CI entry point, staged so the verify loop stays usable:
#
#   scripts/ci.sh fast   — fast tier-1 stage only: pytest -m "not slow"
#                          (the sub-10-minute loop; no benchmarks).
#                          ZERO failures is the contract: the stage exits
#                          non-zero on ANY failed or errored test.  The
#                          pre-existing-failure allowance (10 known model/
#                          sharding failures tolerated through PR 4) is
#                          gone — those tests are fixed, not skipped, and
#                          any new red is a regression.
#   scripts/ci.sh slow   — the slow-marked suites (hypothesis-heavy property
#                          walls, large-n sweeps, multi-device subprocess
#                          tests) + the interpret-mode benchmark smoke pass;
#                          pairs with a separate `fast` job so CI never runs
#                          the fast tier twice
#   scripts/ci.sh faults — fault-matrix smoke only: one resilient oocsort
#                          run per core.faults fault site with retries
#                          enabled, asserting green + byte parity vs the
#                          fault-free run (scripts/fault_matrix.py)
#   scripts/ci.sh dist   — multi-device shard-exchange stage: the
#                          dist-marked subprocess walls at 8 fake devices
#                          (fast rung) and 16 fake devices (slow rung; the
#                          XLA flag is exported so tests/_multidev.py widens
#                          every wall), plus the BENCH_dist.json device-
#                          scaling smoke
#   scripts/ci.sh analyze — static contract analyzer: trace every public
#                          entry point and verify the declared launch
#                          census, sort-free, donation, transfer-byte and
#                          ref-hazard contracts + source lint (compile-only,
#                          no kernel executes; writes ANALYSIS_report.json)
#   scripts/ci.sh [full] — all stages back to back (the one-stop local
#                          verify entry point; dist and analyze run as their
#                          own CI jobs — analyze is repeated in full because
#                          it is seconds-cheap)
#
# Everything runs on a plain CPU host: the Pallas kernels execute in
# interpret mode (the drivers default to it off-TPU), so the fused-engine
# parity and launch-count gates are exercised on every push without TPU
# hardware.  Extra args after the stage name pass through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

STAGE="${1:-full}"
if [[ "$STAGE" == "fast" || "$STAGE" == "slow" || "$STAGE" == "faults" \
      || "$STAGE" == "dist" || "$STAGE" == "analyze" \
      || "$STAGE" == "full" ]]; then
  if [[ $# -gt 0 ]]; then shift; fi
else
  STAGE="full"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# a stage whose marker filter plus user-supplied pass-through args (-k ...)
# collects zero tests exits 5; that is fine for a tier, not a failure of the
# script.  Without pass-through args an empty tier means the marker setup is
# broken and must stay fatal.
PASSTHROUGH=$#
run_stage() {
  local rc=0
  python -m pytest -q "$@" || rc=$?
  if [[ $rc -eq 5 && $PASSTHROUGH -gt 0 ]]; then
    echo "WARNING: this stage collected 0 tests (tolerated: pass-through" \
         "args may filter out an entire tier)"
    rc=0
  fi
  if [[ $rc -ne 0 ]]; then
    exit "$rc"
  fi
}

if [[ "$STAGE" == "analyze" ]]; then
  echo "=== static contract analyzer (compile-only) ==="
  python -m repro.analysis --json ANALYSIS_report.json
  exit 0
fi

if [[ "$STAGE" == "faults" ]]; then
  echo "=== fault-matrix smoke (one resilient run per fault site) ==="
  python scripts/fault_matrix.py
  exit 0
fi

if [[ "$STAGE" == "dist" ]]; then
  # the exported flag only reaches the multi-device subprocesses
  # (tests/_multidev.py reads it for the default width); in-process tests in
  # the dist marker set would see fake devices, so the marker is reserved
  # for subprocess walls (tests/conftest.py invariant)
  echo "=== dist stage: multi-device walls at 8 devices (fast rung) ==="
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    run_stage -m "dist and not slow" "$@"
  echo "=== dist stage: multi-device walls at 16 devices (slow rung) ==="
  XLA_FLAGS="--xla_force_host_platform_device_count=16" \
    run_stage -m "dist and slow" "$@"
  echo "=== dist stage: device-scaling bench smoke (BENCH_dist.json) ==="
  python -m benchmarks.dist --smoke
  python - <<'EOF'
import json
rows = json.load(open("BENCH_dist.json"))
for note in rows.get("notes", []):
    print("WARNING [BENCH_dist.json]:", note)
print("BENCH_dist.json rows:",
      sum(1 for k in rows if k not in ("notes", "ratio_convention")))
EOF
  exit 0
fi

if [[ "$STAGE" != "slow" ]]; then
  echo "=== tier-1 tests (fast stage: -m 'not slow') ==="
  run_stage -m "not slow" "$@"
fi

if [[ "$STAGE" == "fast" ]]; then
  exit 0
fi

# faults and analyze run as their own CI jobs; in the local one-stop `full`
# entry point they slot between the tiers
if [[ "$STAGE" == "full" ]]; then
  echo "=== static contract analyzer (compile-only) ==="
  python -m repro.analysis --json ANALYSIS_report.json
  echo "=== fault-matrix smoke (one resilient run per fault site) ==="
  python scripts/fault_matrix.py
fi

# smoke benches run BEFORE the slow suite so the BENCH artifacts exist even
# when a slow test fails (the upload step runs if: always())
echo "=== benchmark smoke (interpret mode, engine + entropy + ooc + spill + faults) ==="
python -m benchmarks.run --json BENCH_smoke.json --smoke --entropy --ooc --spill --faults

echo "=== tier-1 tests (slow stage: -m slow) ==="
run_stage -m "slow" "$@"

echo "=== smoke bench notes ==="
python - <<'EOF'
import json
for path in ("BENCH_smoke.json", "BENCH_ooc.json"):
    rows = json.load(open(path))
    for note in rows.get("notes", []):
        print(f"WARNING [{path}]:", note)
    print(f"{path} rows:", sum(1 for k in rows if k != "notes"))
EOF

