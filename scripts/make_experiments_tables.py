"""Generate the §Dry-run / §Roofline markdown tables from dry-run artifacts.

Usage: PYTHONPATH=src python scripts/make_experiments_tables.py [baseline_dir] [opt_dir]
Writes artifacts/tables.md with all tables; EXPERIMENTS.md includes them.
"""
import glob
import json
import os
import sys


def load(out_dir):
    arts = {}
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if path.endswith("summary.json"):
            continue
        a = json.load(open(path))
        arts[(a["mesh"], a["arch"], a["shape"])] = a
    return arts


def fmt_ms(s):
    return f"{s*1e3:,.0f}"


def roofline_table(arts, mesh):
    rows = ["| arch | shape | step | t_comp ms | t_mem ms | t_coll ms | bound | "
            "useful/HLO | MFU-bound | GiB/chip | fits |",
            "|---|---|---|---:|---:|---:|---|---:|---:|---:|---|"]
    for (m, arch, shape), a in sorted(arts.items()):
        if m != mesh or not a.get("ok"):
            continue
        rows.append(
            f"| {arch} | {shape} | {a['step']} | {fmt_ms(a['t_compute_s'])} "
            f"| {fmt_ms(a['t_memory_s'])} | {fmt_ms(a['t_collective_s'])} "
            f"| {a['bottleneck'][:4]} | {a['useful_flops_frac']:.2f} "
            f"| {a['mfu_bound']*100:.1f}% | {a['mem_per_chip_gib']:.1f} "
            f"| {'Y' if a['fits_16gib'] else 'n'} |")
    return "\n".join(rows)


def compare_table(base, opt, mesh="pod"):
    rows = ["| arch | shape | t_mem ms (base -> opt) | t_coll ms (base -> opt) | "
            "GiB/chip (base -> opt) | bound (opt) |",
            "|---|---|---|---|---|---|"]
    for key in sorted(base):
        m, arch, shape = key
        if m != mesh or key not in opt:
            continue
        b, o = base[key], opt[key]
        if not (b.get("ok") and o.get("ok")):
            continue
        rows.append(
            f"| {arch} | {shape} "
            f"| {fmt_ms(b['t_memory_s'])} -> {fmt_ms(o['t_memory_s'])} "
            f"| {fmt_ms(b['t_collective_s'])} -> {fmt_ms(o['t_collective_s'])} "
            f"| {b['mem_per_chip_gib']:.1f} -> {o['mem_per_chip_gib']:.1f} "
            f"| {o['bottleneck'][:4]} |")
    return "\n".join(rows)


def dryrun_table(arts, skips, mesh):
    rows = ["| arch | shape | step | compile s | args GiB/chip | temp GiB/chip | "
            "collectives (AR/AG/RS/A2A/CP) |",
            "|---|---|---|---:|---:|---:|---|"]
    for (m, arch, shape), a in sorted(arts.items()):
        if m != mesh:
            continue
        if not a.get("ok"):
            continue
        cc = a.get("collective_counts", {})
        counts = "/".join(str(cc.get(k, 0)) for k in
                          ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        ma = a.get("memory_analysis", {})
        rows.append(
            f"| {arch} | {shape} | {a['step']} | {a.get('compile_s', 0):.1f} "
            f"| {ma.get('argument_bytes', 0)/2**30:.2f} "
            f"| {ma.get('temp_bytes', 0)/2**30:.2f} | {counts} |")
    for s in skips:
        if s["mesh"] == mesh:
            rows.append(f"| {s['arch']} | {s['shape']} | SKIP | - | - | - | "
                        f"{s['skipped'][:60]} |")
    return "\n".join(rows)


def main():
    base_dir = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    opt_dir = sys.argv[2] if len(sys.argv) > 2 else "artifacts/dryrun_opt"
    base = load(base_dir)
    opt = load(opt_dir) if os.path.isdir(opt_dir) else {}
    skips = []
    sumpath = os.path.join(opt_dir if opt else base_dir, "summary.json")
    if os.path.exists(sumpath):
        skips = [r for r in json.load(open(sumpath)) if "skipped" in r]

    out = []
    for mesh in ("pod", "multipod"):
        out.append(f"\n### Dry-run — {mesh} mesh "
                   f"({'2x16x16 = 512 chips' if mesh == 'multipod' else '16x16 = 256 chips'})\n")
        out.append(dryrun_table(opt or base, skips, mesh))
    out.append("\n### Roofline — baseline (paper-faithful substrate, naive attention), single pod\n")
    out.append(roofline_table(base, "pod"))
    if opt:
        out.append("\n### Roofline — optimized (flash attention + EP dispatch "
                   "constraints + sharded prefill cache), single pod\n")
        out.append(roofline_table(opt, "pod"))
        out.append("\n### Baseline -> optimized per-cell deltas (single pod)\n")
        out.append(compare_table(base, opt))
        out.append("\n### Roofline — optimized, multi-pod (512 chips)\n")
        out.append(roofline_table(opt, "multipod"))
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/tables.md", "w") as f:
        f.write("\n".join(out))
    print("wrote artifacts/tables.md", len(base), "baseline cells,",
          len(opt), "optimized cells")


if __name__ == "__main__":
    main()
