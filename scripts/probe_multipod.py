"""Probe: can the CPU backend lower+compile 512-way SPMD with the collectives we need?

Run: python scripts/probe_multipod.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import time
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from jax.experimental.shard_map import shard_map

print("devices:", len(jax.devices()))

mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print("mesh ok:", mesh.shape)


def step(x, w):
    # data-parallel batch, model-parallel feature; exercise the standard collectives
    y = x @ w                         # induces all-gather/reduce-scatter under GSPMD
    y = jnp.tanh(y)
    loss = jnp.mean(y ** 2)
    return loss


x = jax.ShapeDtypeStruct((256, 1024), jnp.bfloat16)
w = jax.ShapeDtypeStruct((1024, 4096), jnp.bfloat16)

xs = NamedSharding(mesh, P(("pod", "data"), None))
ws = NamedSharding(mesh, P(None, "model"))

t0 = time.time()
lowered = jax.jit(step, in_shardings=(xs, ws), out_shardings=NamedSharding(mesh, P()))\
    .lower(x, w)
print("lowered in %.1fs" % (time.time() - t0))
t0 = time.time()
compiled = lowered.compile()
print("compiled in %.1fs" % (time.time() - t0))
print("mem:", compiled.memory_analysis())
ca = compiled.cost_analysis()
if isinstance(ca, list):
    ca = ca[0]
print("cost keys:", {k: v for k, v in list(ca.items())[:8]} if hasattr(ca, "items") else ca)

# shard_map with explicit collectives
def smap_fn(x):
    x = jax.lax.psum(x, "data")
    x = jax.lax.all_gather(x, "model")
    x = jax.lax.psum_scatter(x.reshape(-1), "model", scatter_dimension=0, tiled=True)
    x = jax.lax.all_to_all(x.reshape(16, -1), "model", split_axis=0, concat_axis=0, tiled=True)
    x = jax.lax.ppermute(x, "pod", [(0, 1), (1, 0)])
    return x

xin = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
sh = NamedSharding(mesh, P(("pod", "data"), "model"))
f = shard_map(smap_fn, mesh=mesh, in_specs=P(("pod", "data"), "model"),
              out_specs=P(("pod", "data"), "model"), check_rep=False)
t0 = time.time()
low2 = jax.jit(f, in_shardings=(sh,), out_shardings=sh).lower(xin)
comp2 = low2.compile()
print("shard_map collectives compiled in %.1fs" % (time.time() - t0))

hlo = comp2.as_text()
for op in ["all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute"]:
    print(op, hlo.count(op))
print("PROBE OK")
