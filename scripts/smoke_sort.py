import numpy as np
import jax
import jax.numpy as jnp
import sys
sys.path.insert(0, "src")
from repro.core import hybrid_sort, lsd_sort, SortConfig

rng = np.random.default_rng(0)

# tiny threshold config so counting passes actually happen at small n
cfg = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)

for n in [0, 1, 2, 7, 100, 1000, 20000]:
    x = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    out, stats = hybrid_sort(jnp.asarray(x), cfg=cfg, return_stats=True) if n else (jnp.asarray(x), None)
    ok = np.array_equal(np.sort(x), np.asarray(out))
    print(f"n={n:6d} ok={ok} stats={stats}")
    assert ok, f"FAIL n={n}"

# values, skew, int32, float32
n = 5000
for name, x in [
    ("uniform_u32", rng.integers(0, 2**32, n, dtype=np.uint32)),
    ("skew_and3", rng.integers(0, 2**32, n, dtype=np.uint32)
                  & rng.integers(0, 2**32, n, dtype=np.uint32)
                  & rng.integers(0, 2**32, n, dtype=np.uint32)),
    ("const", np.full(n, 12345, dtype=np.uint32)),
    ("int32", rng.integers(-2**31, 2**31, n).astype(np.int32)),
    ("f32", rng.standard_normal(n).astype(np.float32)),
]:
    v = np.arange(n, dtype=np.int32)
    ks, vs, stats = hybrid_sort(jnp.asarray(x), jnp.asarray(v), cfg=cfg, return_stats=True)
    ks, vs = np.asarray(ks), np.asarray(vs)
    assert np.array_equal(np.sort(x), ks), f"keys FAIL {name}"
    assert np.array_equal(x[vs], ks), f"pair consistency FAIL {name}"
    print(f"{name:12s} passes={stats.counting_passes} local={stats.used_local_sort} segs={stats.num_segments}")

# LSD baseline
x = rng.integers(0, 2**32, 4096, dtype=np.uint32)
assert np.array_equal(np.sort(x), np.asarray(lsd_sort(jnp.asarray(x), d=5)))
x = rng.standard_normal(3000).astype(np.float32)
assert np.allclose(np.sort(x), np.asarray(lsd_sort(jnp.asarray(x), d=4)))
print("LSD ok")
print("SMOKE OK")
