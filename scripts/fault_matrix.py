"""Fault-matrix smoke: one resilient oocsort run per fault site, green + parity.

CI's `faults` stage (scripts/ci.sh faults) runs this after the fast tier:
for every site in ``core.faults.FAULT_SITES`` it injects two consecutive
transient faults at that site's first op (``fail_at={site: [0, 1]}``) under
the default bounded-retry policy and asserts the run stays green — output
byte-identical to the fault-free run, the fault actually fired, no
degradation was needed, the device high-water stayed under the budget, and
the link-byte identity ``h2d + d2h == chunk_link + spill_link + retry_link``
held exactly.  The ``host_corruption`` pseudo-site runs with a checkpoint
directory so the detected corruption recovers from the round checkpoint
instead of raising.

Every run shares one spill plan (same shapes), so the jit cache is warm
after the baseline and the whole matrix stays a smoke test, not a bench.
"""
from __future__ import annotations

import argparse
import sys
import tempfile

import numpy as np

from repro.core.faults import FAULT_SITES, FaultPolicy, RetryPolicy
from repro.core.outofcore import oocsort


def run_matrix(n: int = 3000, chunk: int = 700, tile: int = 16,
               budget: int = 4096, seed: int = 0) -> int:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    vals = np.arange(n, dtype=np.uint32)
    want_k, want_v, base = oocsort(keys, chunk, values=vals, tile=tile,
                                   spill_budget_bytes=budget,
                                   return_stats=True)
    print(f"baseline: n={n} chunks={base.num_chunks} "
          f"rounds={base.rounds_spilled} hw={base.device_high_water_bytes}")
    failed = 0
    for site in FAULT_SITES:
        policy = FaultPolicy(seed=seed, fail_at={site: [0, 1]})
        retry = RetryPolicy(max_retries=3)
        kwargs = {}
        ctx = tempfile.TemporaryDirectory()
        with ctx as ckpt_dir:
            if site == "host_corruption":
                # detected corruption recovers from the round checkpoint
                kwargs["checkpoint_dir"] = ckpt_dir
            got_k, got_v, st = oocsort(
                keys, chunk, values=vals, tile=tile,
                spill_budget_bytes=budget, faults=policy, retry=retry,
                return_stats=True, **kwargs)
        problems = []
        if not np.array_equal(got_k, want_k):
            problems.append("keys differ from fault-free run")
        if not np.array_equal(got_v, want_v):
            problems.append("values differ from fault-free run")
        if site == "host_corruption":
            if st.checksum_failures < 1:
                problems.append("corruption was injected but never detected")
        elif st.faults_injected < 2:
            problems.append(f"expected 2 injected faults, saw "
                            f"{st.faults_injected}")
        if st.degradations:
            problems.append(f"{st.degradations} degradations (retries alone "
                            f"should have absorbed 2 transients)")
        if st.device_high_water_bytes > budget:
            problems.append(f"high water {st.device_high_water_bytes} > "
                            f"budget {budget}")
        if st.h2d_bytes + st.d2h_bytes != (st.chunk_link_bytes +
                                           st.spill_link_bytes +
                                           st.retry_link_bytes):
            problems.append("link-byte identity violated")
        status = "ok" if not problems else "FAIL"
        print(f"{site:16s} {status}  faults={st.faults_injected} "
              f"retries={st.retries} checksum_failures={st.checksum_failures} "
              f"retry_link_bytes={st.retry_link_bytes}")
        for p in problems:
            print(f"                 - {p}")
        failed += bool(problems)
    if failed:
        print(f"FAULT MATRIX: {failed}/{len(FAULT_SITES)} sites FAILED")
        return 1
    print(f"FAULT MATRIX: all {len(FAULT_SITES)} sites green")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--chunk", type=int, default=700)
    ap.add_argument("--tile", type=int, default=16)
    ap.add_argument("--budget", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return run_matrix(n=args.n, chunk=args.chunk, tile=args.tile,
                      budget=args.budget, seed=args.seed)


if __name__ == "__main__":
    sys.exit(main())
