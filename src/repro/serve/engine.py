"""Batched serving engine: prefill + decode with a static-shape KV cache.

The scheduler orders the admission queue with a counting pass on the
remaining-length class (repro.core.segmented) — short-remaining requests are
co-batched so a slot never idles behind a long straggler longer than one
class width: the paper's partitioning machinery doing decode-batch straggler
mitigation.

Queues past device memory (the north-star "heavy traffic" regime) route the
admission sort through the §5 out-of-core pipeline instead: an
:class:`AdmissionConfig` switches ``schedule`` to ``core.outofcore.oocsort``
over the remaining-length classes, with the device footprint bounded by
``spill_budget_bytes`` and — because an admission sort that crashes drops
every queued request — the ``core.faults`` resilience layer (fault policy,
bounded retries, degradation ladder, round-granular checkpoints) threaded
straight through.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.segmented import counting_partition
from repro.models import decode_step, forward, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    generated: Optional[np.ndarray] = None


LENGTH_CLASS = 64                         # remaining-length bucket width


@dataclasses.dataclass
class AdmissionConfig:
    """Out-of-core admission sorting for queues past device memory.

    When set on :class:`ServeEngine`, ``schedule`` orders the queue through
    ``core.outofcore.oocsort`` instead of a single device counting pass:
    the remaining-length classes stream through chunk sorts + k-way merge
    rounds, device bytes bounded by ``spill_budget_bytes`` /
    ``device_slab_elems``, and the ``core.faults`` resilience layer —
    ``faults`` (a ``FaultPolicy``), ``retry`` (a ``RetryPolicy``) and
    ``checkpoint_dir`` — rides along so an admission sort over a huge queue
    retries, degrades and resumes instead of dropping the queue.
    """
    chunk_elems: int
    spill_budget_bytes: Optional[int] = None
    device_slab_elems: Optional[int] = None
    faults: Optional[object] = None       # core.faults.FaultPolicy
    retry: Optional[object] = None        # core.faults.RetryPolicy
    checkpoint_dir: Optional[str] = None


class ServeEngine:
    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 admission: Optional[AdmissionConfig] = None):
        self.cfg, self.params = cfg, params
        self.batch = batch_size
        self.max_len = max_len
        self.admission = admission
        self._decode = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))

    def schedule(self, queue: List[Request]) -> List[List[Request]]:
        """Sort-based admission: group by remaining-length class (one counting
        pass — or the resilient out-of-core route under an
        :class:`AdmissionConfig`), then fill fixed-size batches class-major."""
        if not queue:
            return []
        cls = [min(r.max_new_tokens // LENGTH_CLASS, 255) for r in queue]
        if self.admission is not None:
            from repro.core.outofcore import oocsort
            adm = self.admission
            _, order = oocsort(
                np.asarray(cls, np.uint32), adm.chunk_elems,
                values=np.arange(len(queue), dtype=np.int32),
                spill_budget_bytes=adm.spill_budget_bytes,
                device_slab_elems=adm.device_slab_elems,
                faults=adm.faults, retry=adm.retry,
                checkpoint_dir=adm.checkpoint_dir)
        else:
            part = counting_partition(jnp.asarray(cls, jnp.int32), 256)
            order = np.asarray(part.perm)
        batches = []
        for i in range(0, len(queue), self.batch):
            batches.append([queue[j] for j in order[i:i + self.batch]])
        return batches

    def _prefill(self, reqs: List[Request]):
        b = len(reqs)
        lens = [len(r.prompt) for r in reqs]
        s = max(lens)
        toks = np.zeros((self.batch, s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, s - len(r.prompt):] = r.prompt       # left-pad
        cache = init_cache(self.cfg, self.batch, self.max_len)
        # teacher-forced prefill through the decode path (single code path,
        # static shapes; production would use a chunked prefill kernel)
        tokens = jnp.asarray(toks)
        logits = None
        for t in range(s):
            logits, cache = self._decode(self.params, tokens[:, t:t + 1], cache)
        return logits, cache

    def generate(self, reqs: List[Request], greedy: bool = True):
        reqs = reqs[: self.batch]
        logits, cache = self._prefill(reqs)
        max_new = max(r.max_new_tokens for r in reqs)
        outs = np.zeros((self.batch, max_new), np.int32)
        cur = jnp.argmax(logits[:, -1, : self.cfg.vocab], axis=-1)[:, None]
        for t in range(max_new):
            outs[:, t] = np.asarray(cur)[:, 0]
            logits, cache = self._decode(self.params, cur.astype(jnp.int32), cache)
            cur = jnp.argmax(logits[:, -1, : self.cfg.vocab], axis=-1)[:, None]
        for i, r in enumerate(reqs):
            r.generated = outs[i, : r.max_new_tokens]
        return reqs
