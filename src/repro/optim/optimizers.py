"""Optimizers built from scratch (no optax): AdamW, Adafactor, 8-bit AdamW.

The three tiers trade per-parameter state bytes for fidelity — the knob that
decides whether a 1T-parameter model fits a 16 GB/chip pod:

  adamw      m,v fp32            + 8 B/param   (default, <= ~70B params)
  adamw8bit  m,v int8 + scales   + ~2 B/param  (block-quantised states)
  adafactor  v factored row/col  + ~0 B/param  (kimi-k2 tier)

API (optax-like): ``opt.init(params) -> state``; ``opt.update(grads, state,
params, lr) -> (new_params, new_state)``.  All updates are donation-friendly
(pure pytree maps, no aliasing surprises).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (grads, state, params, lr) -> (params, state)


def clip_by_global_norm(grads, max_norm: float):
    """Global-norm clip without materialising an f32 copy of the gradients:
    the norm accumulates in f32 scalars; the rescale happens in each leaf's
    own dtype (a 1T-param model saves ~16 GiB/chip of transient f32)."""
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


# --------------------------------- AdamW ------------------------------------

def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1):
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "count": c}

    return Optimizer(init, update)


# ------------------------------- Adafactor ----------------------------------

def adafactor(eps=1e-30, clip_threshold=1.0, decay=0.8, weight_decay=0.0):
    """Factored second moments: O(rows+cols) state for matrices — the memory
    tier that lets kimi-k2 (1T params) train on a 16 GB/chip pod."""
    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"s": jax.tree.map(st, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        beta = 1.0 - (c.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)
                # factored rsqrt: u = g * rsqrt(vr/denom) * rsqrt(vc) — never
                # materialises the dense (rows x cols) f32 vhat (the 1T-param
                # memory spike the §Perf log chases)
                rs_r = jax.lax.rsqrt(jnp.maximum(vr / jnp.maximum(denom, eps), eps))
                rs_c = jax.lax.rsqrt(jnp.maximum(vc, eps))
                u = g * rs_r[..., None] * rs_c[..., None, :]
                ns = {"vr": vr, "vc": vc}
            else:
                vhat = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(vhat, eps))
                ns = {"v": vhat}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        out = jax.tree.map(upd, grads, state["s"], params,
                           is_leaf=lambda x: isinstance(x, dict) and
                           ("v" in x or "vr" in x))
        is_pair = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
        new_s = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
        return new_p, {"s": new_s, "count": c}

    return Optimizer(init, update)


# ------------------------------- 8-bit AdamW --------------------------------

_BLOCK = 256


def _quant(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    fb = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(fb), axis=1, keepdims=True) / 127.0
    q = jnp.round(fb / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q, scale, shape):
    import math
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[: math.prod(shape)].reshape(shape)


def adamw8bit(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1):
    """AdamW with block-quantised int8 m/v states (~2 B/param instead of 8)."""
    def init(params):
        def st(p):
            q, s = _quant(jnp.zeros_like(p, dtype=jnp.float32))
            return {"mq": q, "ms": s, "vq": q, "vs": s}
        return {"s": jax.tree.map(st, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            m = b1 * _dequant(s["mq"], s["ms"], p.shape) + (1 - b1) * g
            v = b2 * _dequant(s["vq"], s["vs"], p.shape) + (1 - b2) * jnp.square(g)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            mq, ms = _quant(m)
            vq, vs = _quant(v)
            return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                    {"mq": mq, "ms": ms, "vq": vq, "vs": vs})

        out = jax.tree.map(upd, grads, state["s"], params,
                           is_leaf=lambda x: isinstance(x, dict) and "mq" in x)
        is_pair = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
        new_s = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
        return new_p, {"s": new_s, "count": c}

    return Optimizer(init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "adamw8bit": adamw8bit}[name](**kw)
