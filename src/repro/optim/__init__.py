from repro.optim.optimizers import (adamw, adafactor, adamw8bit, get_optimizer,
                                    clip_by_global_norm, cosine_schedule)
from repro.optim.compression import int8_compress, int8_decompress

__all__ = ["adamw", "adafactor", "adamw8bit", "get_optimizer",
           "clip_by_global_norm", "cosine_schedule",
           "int8_compress", "int8_decompress"]
