"""Gradient compression for the slow inter-pod link (distributed-opt trick).

Hierarchical gradient reduction: reduce-scatter in full precision over the
fast intra-pod ICI, then compress to int8 (block-scaled) for the all-reduce
across the `pod` axis (data-center interconnect), then decompress.  4x fewer
bytes over the slowest link at <1e-2 relative error on gradient noise scales.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_BLOCK = 512


def int8_compress(x: jnp.ndarray):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    fb = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(fb), axis=1, keepdims=True) / 127.0
    q = jnp.round(fb / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[: math.prod(shape)].reshape(shape)


def compressed_psum(x: jnp.ndarray, axis_name: str):
    """psum over ``axis_name`` with int8 wire format (use over the pod axis).

    The payload crossing the link is int8 + per-block f32 scales (~4x fewer
    bytes than bf16 all-reduce for small pod counts): all_gather the
    quantised blocks, dequantise and sum locally.  Quantisation error is
    bounded by the per-block max/127 — measured against exact psum in tests.
    """
    q, s = int8_compress(x)
    qs = jax.lax.all_gather(q, axis_name)          # (P, blocks, _BLOCK) int8 wire
    ss = jax.lax.all_gather(s, axis_name)
    summed = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    return summed.reshape(-1)[: math.prod(x.shape)].reshape(x.shape).astype(x.dtype)
