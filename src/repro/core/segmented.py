"""Counting-sort partitioning primitives reused across the framework.

``counting_partition`` is one hybrid-radix counting pass (paper §4.1 steps
1–3) exposed as a standalone op — a thin client of
``core.plan.single_pass_partition``, the engine-selected implementation every
layer shares.  It is the core of:

  * MoE token dispatch (group tokens expert-major; E <= 2^d ⇒ exactly one pass),
  * data-pipeline length bucketing,
  * the shard-partitioning step of the distributed sort (§5).

``engine=None`` resolves exactly like the sort drivers
(``core.plan.resolve_pass_engine``: the fused Pallas ``kernel`` launch
wherever Pallas interprets, ``argsort`` on compiled hardware).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core import plan


class Partition(NamedTuple):
    dest: jnp.ndarray     # (m,) slot of element i in bucket-major order
    perm: jnp.ndarray     # (m,) gather order: sorted[j] = x[perm[j]]
    counts: jnp.ndarray   # (num_buckets,)
    offsets: jnp.ndarray  # (num_buckets,) exclusive prefix of counts


def counting_partition(bucket_ids: jnp.ndarray, num_buckets: int,
                       engine: Optional[str] = None,
                       interpret: Optional[bool] = None) -> Partition:
    """Stable partition of elements by ``bucket_ids`` (one counting pass)."""
    dest, perm, counts = plan.single_pass_partition(
        bucket_ids, num_buckets, engine=engine, interpret=interpret)
    offsets = (jnp.cumsum(counts) - counts).astype(jnp.int32)
    return Partition(dest=dest, perm=perm, counts=counts, offsets=offsets)


class CapacityDispatch(NamedTuple):
    """Bucket-major, capacity-padded gather layout (the MoE dispatch shape)."""
    gather_idx: jnp.ndarray   # (num_buckets, capacity) source element per slot
    slot_valid: jnp.ndarray   # (num_buckets, capacity) bool
    position: jnp.ndarray     # (m,) element's slot within its bucket
    kept: jnp.ndarray         # (m,) bool — False if dropped by capacity
    counts: jnp.ndarray       # (num_buckets,)


def capacity_dispatch(bucket_ids: jnp.ndarray, num_buckets: int, capacity: int,
                      engine: Optional[str] = None) -> CapacityDispatch:
    """Counting-sort dispatch into a dense (buckets, capacity) layout.

    This is the paper's scatter step with the destination chunk *reserved* per
    bucket (§4.4) — here the reservation is the static capacity row.  Elements
    beyond capacity are marked dropped (standard MoE semantics).
    """
    m = bucket_ids.shape[0]
    part = counting_partition(bucket_ids, num_buckets, engine=engine)
    position = part.dest - part.offsets[bucket_ids]
    kept = position < capacity
    slot = jnp.where(kept, bucket_ids * capacity + position, num_buckets * capacity)
    gather_flat = jnp.full((num_buckets * capacity + 1,), m, jnp.int32)
    gather_flat = gather_flat.at[slot].set(jnp.arange(m, dtype=jnp.int32), mode="drop")
    gather_idx = gather_flat[:-1].reshape(num_buckets, capacity)
    slot_valid = gather_idx < m
    return CapacityDispatch(gather_idx=gather_idx, slot_valid=slot_valid,
                            position=position.astype(jnp.int32), kept=kept,
                            counts=part.counts)


def merge_sorted(a: jnp.ndarray, b: jnp.ndarray, va=None, vb=None):
    """Parallel merge of two sorted arrays (GPU merge-path analogue, §5's
    multiway merge building block) via vectorised binary search; optional
    values ride along (§4.6 pair semantics)."""
    na, nb = a.shape[0], b.shape[0]
    out = jnp.zeros((na + nb,), a.dtype)
    pos_a = jnp.arange(na) + jnp.searchsorted(b, a, side="left")
    pos_b = jnp.arange(nb) + jnp.searchsorted(a, b, side="right")
    merged = out.at[pos_a].set(a).at[pos_b].set(b)
    if va is None:
        return merged
    vout = jnp.zeros((na + nb,), va.dtype).at[pos_a].set(va).at[pos_b].set(vb)
    return merged, vout


def multiway_merge(runs: jnp.ndarray, values=None):
    """Merge (s, run_len) sorted runs by pairwise reduction (log2 s passes);
    optional (s, run_len) values permute alongside."""
    s = runs.shape[0]
    flat = [runs[i] for i in range(s)]
    vals = [values[i] for i in range(s)] if values is not None else None
    while len(flat) > 1:
        nxt, vnxt = [], []
        for i in range(0, len(flat) - 1, 2):
            if vals is None:
                nxt.append(merge_sorted(flat[i], flat[i + 1]))
            else:
                m, vm = merge_sorted(flat[i], flat[i + 1], vals[i], vals[i + 1])
                nxt.append(m)
                vnxt.append(vm)
        if len(flat) % 2:
            nxt.append(flat[-1])
            if vals is not None:
                vnxt.append(vals[-1])
        flat = nxt
        if vals is not None:
            vals = vnxt
    return flat[0] if values is None else (flat[0], vals[0])
