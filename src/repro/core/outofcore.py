"""Out-of-core pipelined sort: chunked device runs + streaming k-way merges.

The paper's second headline (§5, the 64 GB end-to-end result) sorts inputs
that exceed device memory with a chunk-sort-then-merge pipeline: the host
array streams to the device in chunks, every chunk is sorted on-device while
the next chunk's transfer is in flight, and the sorted runs are merged by a
device merge kernel.  ``oocsort`` is that pipeline in JAX terms — the
*sort* phase works in chunk-sized device buffers; the merge phase currently
keeps the full flat run buffer on device (one launch per round over every
group), so true beyond-device-memory capacity waits on the host-spill
streaming of group-sized merge slabs (ROADMAP open item).  The pipeline
structure, accounting and census are the §5 shape:

  1. the host-resident input (array or chunk iterator) is re-chunked into
     runs of ``chunk_elems`` keys (+ value slabs),
  2. chunk i+1 is staged with ``jax.device_put`` *before* chunk i's sort is
     consumed — JAX dispatch is asynchronous, so the upload and the sort
     overlap (double buffering, the §5 transfer/compute pipeline),
  3. each chunk is sorted by ``hybrid_sort`` — the fused single-launch
     counting-pass engine on donated ping-pong buffers (PR 1–2) — and mapped
     to order-preserving unsigned bits so runs merge bitwise,
  4. ⌈log_K(runs)⌉ rounds of the merge-path kernel
     (``kernels.merge.kway_merge_round``) fuse K adjacent runs per group,
     ONE Pallas launch per round, ping-pong buffers donated between rounds,
  5. the merged keys map back to the key dtype and land on the host.

Transfer accounting (§5): every key crosses the host link exactly twice
(staged in chunks overlapped with compute, gathered once at the end), and
device memory sweeps total ``(2·⌈k/d⌉ + 1)`` for the chunk sorts (§4.3/§4.4
accounting at chunk size), plus one run-marshalling sweep (1R + 2W:
concatenating the sorted runs into the flat merge buffer and allocating its
sentinel-filled alternate), plus ``2·⌈log_K(C)⌉`` for the merge rounds —
the table in ``repro.kernels``'s docstring.

Determinism: the merge breaks ties by (key, run, position), so runs of equal
keys keep chunk order and the output is a pure function of the input stream
and the chunking — byte-identical across engines, certified by the oocsort
parity wall.
"""
from __future__ import annotations

import functools
from typing import Any, Iterable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bijection, model
from repro.core.hybrid import hybrid_sort
from repro.kernels import merge as kmerge
from repro.kernels.fused import pad_length


class OocStats(NamedTuple):
    num_chunks: int      # sorted device runs the input was split into
    merge_rounds: int    # ⌈log_kway(num_chunks)⌉ merge-kernel rounds
    chunk_elems: int     # device chunk capacity the plan used
    h2d_bytes: int       # host->device bytes staged (keys + values)
    d2h_bytes: int       # device->host bytes gathered at the end


def _as_stream(reader, values):
    """Normalise the input to a stream of (keys, values-or-None) pieces."""
    if hasattr(reader, "shape") and hasattr(reader, "dtype"):
        yield reader, values
        return
    if values is not None:
        raise ValueError("with an iterator reader, pass values inline as "
                         "(keys, values) tuples")
    for item in reader:
        if isinstance(item, tuple):
            yield item
        else:
            yield item, None


def _rechunk(stream, chunk_elems: int):
    """Re-cut a stream of (keys, values) pieces into device-sized chunks.

    Returns ``(chunks, treedef, key_dtype, empty_leaves)`` where each chunk
    is ``(keys, value_leaves)`` with ``len(keys) <= chunk_elems`` (only the
    last chunk may be short) and ``empty_leaves`` are zero-length prototypes
    of the value leaves.  Host-side only: pieces are numpy views/copies.
    """
    buf_k, buf_v = [], []
    chunks = []
    treedef = None
    key_dtype = None
    empty_leaves = ()
    pending = 0

    def emit(upto):
        nonlocal buf_k, buf_v, pending
        k = np.concatenate(buf_k) if len(buf_k) > 1 else buf_k[0]
        vs = [np.concatenate(c) if len(c) > 1 else c[0] for c in buf_v]
        chunks.append((k[:upto], tuple(v[:upto] for v in vs)))
        buf_k = [k[upto:]] if upto < k.shape[0] else []
        buf_v = [[v[upto:]] for v in vs] if upto < k.shape[0] else \
            [[] for _ in vs]
        pending -= upto

    for keys, vals in stream:
        keys = np.asarray(keys)
        if keys.ndim != 1:
            raise ValueError("oocsort expects 1-D key chunks")
        leaves, td = jax.tree.flatten(vals)
        leaves = [np.asarray(v) for v in leaves]
        if treedef is None:
            treedef, key_dtype = td, keys.dtype
            empty_leaves = tuple(v[:0] for v in leaves)
            buf_v = [[] for _ in leaves]
        elif td != treedef:
            raise ValueError("inconsistent value structure across chunks")
        if keys.dtype != key_dtype:
            raise ValueError(f"inconsistent key dtype across chunks: "
                             f"{keys.dtype} vs {key_dtype}")
        if any(v.dtype != p.dtype for v, p in zip(leaves, empty_leaves)):
            raise ValueError("inconsistent value dtypes across chunks")
        if any(v.ndim != 1 for v in leaves):
            raise ValueError("oocsort value leaves must be 1-D (the merge "
                             "kernel moves flat per-key slabs)")
        if any(v.shape[0] != keys.shape[0] for v in leaves):
            raise ValueError("value leaves must match the key length")
        if keys.shape[0] == 0:
            continue
        buf_k.append(keys)
        for c, v in zip(buf_v, leaves):
            c.append(v)
        pending += keys.shape[0]
        while pending >= chunk_elems:
            emit(chunk_elems)
    if pending:
        emit(pending)
    return chunks, treedef, key_dtype, empty_leaves


@functools.partial(jax.jit, static_argnames=("cfg", "engine", "interpret"))
def _sort_chunk(keys, leaves, cfg, engine, interpret):
    """Sort one staged chunk; emit the run as order-preserving unsigned bits."""
    if leaves:
        sk, sv = hybrid_sort(keys, leaves, cfg=cfg, engine=engine,
                             interpret=interpret)
    else:
        sk = hybrid_sort(keys, cfg=cfg, engine=engine, interpret=interpret)
        sv = ()
    return bijection.to_ordered_bits(sk), sv


@functools.partial(jax.jit, static_argnames=("lens", "kway", "tile", "n",
                                             "interpret"),
                   donate_argnums=(2, 3))
def merge_round(src_keys, src_vals, alt_keys, alt_vals, *, lens, kway: int,
                tile: int, n: int, interpret: bool = True):
    """One k-way merge round: diagonal partition + ONE merge-kernel launch.

    ``lens`` is the static tuple of current run lengths; groups of up to
    ``kway`` adjacent runs merge into one run each.  The partition tables are
    sort-free binary searches; the data movement is the single
    ``kway_merge_round`` launch (the per-round census gate).  The alternate
    buffers are donated.
    """
    tables = kmerge.merge_path_partition(src_keys, lens, kway, tile)
    return kmerge.kway_merge_round(src_keys, src_vals, alt_keys, alt_vals,
                                   *tables, kway=kway, tpb=tile, n=n,
                                   interpret=interpret)


def oocsort(reader, chunk_elems: int, values: Any = None,
            cfg: Optional[model.SortConfig] = None,
            engine: Optional[str] = None, interpret: Optional[bool] = None,
            kway: int = 4, tile: int = 256, return_stats: bool = False):
    """Sort a host-resident array (or chunk stream) larger than one device run.

    ``reader`` is a 1-D numpy array, an iterable of 1-D key chunks (all of
    one dtype), or an iterable of ``(keys, values)`` chunk tuples;
    ``values`` (array-input only) is a 1-D array or pytree of 1-D arrays
    permuted alongside the keys (flat per-key slabs — the merge kernel's
    payload layout).  The
    input is cut into runs of ``chunk_elems`` keys; each run is sorted
    on-device by ``hybrid_sort`` (``cfg``/``engine`` as there) while the next
    chunk's ``jax.device_put`` is in flight, and the runs are merged by
    ⌈log_``kway``⌉ rounds of the merge-path kernel, one Pallas launch per
    round on donated ping-pong buffers.

    Returns host numpy arrays: ``sorted_keys``, or ``(sorted_keys,
    permuted_values)`` when values were given; append an :class:`OocStats`
    when ``return_stats``.  Pair movement is consistent but — like
    ``hybrid_sort`` — not stable across equal keys *within* a chunk; across
    chunks the merge keeps run order (ties break by run index).
    """
    if chunk_elems < 1:
        raise ValueError("chunk_elems must be >= 1")
    if kway < 2:
        raise ValueError("kway must be >= 2")
    if tile < 8:
        raise ValueError("tile must be >= 8")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    chunks, treedef, key_dtype, empty_leaves = _rechunk(
        _as_stream(reader, values), chunk_elems)
    had_values = treedef is not None and treedef.num_leaves > 0

    def finish(keys_np, leaves_np, stats):
        out = (keys_np,) if not had_values else \
            (keys_np, jax.tree.unflatten(treedef, list(leaves_np)))
        if return_stats:
            out = out + (stats,)
        return out[0] if len(out) == 1 else out

    if not chunks:
        if key_dtype is None:
            raise ValueError("empty iterator reader: yield at least one "
                             "(possibly empty) chunk to fix the dtype")
        stats = OocStats(0, 0, chunk_elems, 0, 0)
        return finish(np.empty((0,), key_dtype), empty_leaves, stats)

    k = bijection.key_bits(key_dtype)
    if k > 32 and not jax.config.jax_enable_x64:
        raise RuntimeError("64-bit keys require jax_enable_x64")
    if not jax.config.jax_enable_x64:
        # leaf dtypes are chunk-uniform (_rechunk), so one chunk decides;
        # device_put would otherwise silently truncate 64-bit payloads
        for v in chunks[0][1]:
            if v.dtype.itemsize > 4:
                raise RuntimeError(
                    f"64-bit value leaves ({v.dtype}) require "
                    "jax_enable_x64")

    # --- chunk phase: double-buffered staging, §5's upload/sort overlap ----
    h2d = 0
    runs = []
    staged = jax.device_put(chunks[0])
    h2d += chunks[0][0].nbytes + sum(v.nbytes for v in chunks[0][1])
    for nxt in chunks[1:]:
        nxt_dev = jax.device_put(nxt)           # stage i+1 ...
        h2d += nxt[0].nbytes + sum(v.nbytes for v in nxt[1])
        runs.append(_sort_chunk(*staged, cfg, engine, interpret))  # sort i
        staged = nxt_dev
    runs.append(_sort_chunk(*staged, cfg, engine, interpret))
    num_chunks = len(chunks)
    lens = [c[0].shape[0] for c in chunks]
    n = sum(lens)

    # --- merge phase: flat ping-pong run buffers, one launch per round -----
    rounds = 0
    if num_chunks == 1:
        ck, cv = runs[0]             # single run: no marshalling, no merge
    else:
        # the padded current/alternate buffers follow fused.make_ping_pong's
        # contract (sentinel key pad, zero value pad), built inline so run
        # marshalling is a single concatenate — one fewer sweep than padding
        # a pre-concatenated copy
        udtype = runs[0][0].dtype
        n_pad = pad_length(n, tile)
        sentinel = ~jnp.zeros((), udtype)
        ck = jnp.concatenate([r[0] for r in runs] +
                             [jnp.full((n_pad - n,), sentinel, udtype)])
        num_leaves = len(runs[0][1])
        cv = tuple(
            jnp.concatenate([r[1][i] for r in runs] +
                            [jnp.zeros((n_pad - n,), runs[0][1][i].dtype)])
            for i in range(num_leaves))
        ak = jnp.full_like(ck, sentinel)
        av = tuple(jnp.zeros_like(v) for v in cv)
        del runs, staged, chunks     # release the per-run device buffers:
        # the merge phase's footprint is the two flat ping-pong buffers only

        while len(lens) > 1:
            nk, nv = merge_round(ck, cv, ak, av, lens=tuple(lens), kway=kway,
                                 tile=tile, n=n, interpret=interpret)
            ak, av = ck, cv                  # old current donates next round
            ck, cv = nk, nv
            lens = [sum(g) for g in kmerge.merge_groups(lens, kway)]
            rounds += 1

    keys_np = np.asarray(bijection.from_ordered_bits(ck[:n], key_dtype))
    leaves_np = tuple(np.asarray(v[:n]) for v in cv)
    d2h = keys_np.nbytes + sum(v.nbytes for v in leaves_np)
    stats = OocStats(num_chunks, rounds, chunk_elems, h2d, d2h)
    return finish(keys_np, leaves_np, stats)
