"""Out-of-core pipelined sort: chunked device runs + streaming k-way merges.

The paper's second headline (§5, the 64 GB end-to-end result) sorts inputs
that exceed device memory with a chunk-sort-then-merge pipeline: the host
array streams to the device in chunks, every chunk is sorted on-device while
the next chunk's transfer is in flight, and the sorted runs are merged by a
device merge kernel.  ``oocsort`` is that pipeline in JAX terms, in two
device-memory regimes:

  1. the host-resident input (array or chunk iterator) is re-chunked into
     runs of ``chunk_elems`` keys (+ value slabs),
  2. chunk i+1 is staged with ``jax.device_put`` *before* chunk i's sort is
     consumed — JAX dispatch is asynchronous, so the upload and the sort
     overlap (double buffering, the §5 transfer/compute pipeline),
  3. each chunk is sorted by ``hybrid_sort`` — the fused single-launch
     counting-pass engine on donated ping-pong buffers (PR 1–2) — and mapped
     to order-preserving unsigned bits so runs merge bitwise,
  4. **device-resident merge** (default): ⌈log_K(runs)⌉ rounds of the
     merge-path kernel (``kernels.merge.kway_merge_round``) fuse K adjacent
     runs per group, ONE Pallas launch per round, ping-pong buffers donated
     between rounds — the whole flat run buffer lives on device,
  5. **host-spill streaming merge** (``spill_budget_bytes`` /
     ``device_slab_elems``): runs live *host-side* between rounds and every
     round streams its groups through a bounded handful of slab-sized
     device buffers (double-buffered sources, alternates and descriptor
     tables — ``_SLAB_FOOTPRINT`` models the worst case).  The
     merge-path co-ranks are computed from O(bits·K·log L) probed host
     elements per diagonal (``kernels.merge.host_coranks``), each group is cut into slab-sized
     strips of whole output tiles (``kernels.merge.spill_group_plan``), and
     strip i+1's ``device_put`` upload plus strip i−1's download are in
     flight while strip i's ``kway_merge_round`` launch runs — the chunk
     phase's double-buffering discipline extended with D2H, ONE Pallas
     launch per group-slab sweep.  Device memory stays bounded by the slab
     budget no matter how large the input,
  6. the merged keys map back to the key dtype and land on the host (the
     spill path inverts the bit bijection in numpy — no extra device trip).

Failure story (``core.faults``): every transfer and launch site above —
chunk uploads, sort launches, run downloads, strip uploads/downloads, merge
launches — runs through :func:`repro.core.faults.guarded`: an injectable
:class:`~repro.core.faults.FaultPolicy` (deterministic, seed-driven) plus a
bounded-retry :class:`~repro.core.faults.RetryPolicy`.  Host-resident runs
carry xxhash-style checksums recorded at each host crossing and verified
before consumption, so silent host-buffer corruption surfaces as
``ChecksumError`` instead of wrong output.  On exhausted retries the driver
walks a **degradation ladder** instead of crashing: halve the device slab
(floor ``tile``), then halve the merge fan-in ``kway`` (floor 2), then
re-chunk with halved ``chunk_elems`` — each rung re-validated against
``spill_budget_bytes`` so the device high-water gate still holds.  Rungs 1–2
leave the output byte-identical (the merge is grouping-invariant); the
re-chunk rung preserves key bytes always and KV bytes when keys are unique
(run boundaries move, so pair order across equal keys may change).  With
``checkpoint_dir`` the spill merge is **round-granular checkpointed**: after
each merge round the host-resident runs plus a manifest (round index, plan,
run lengths, checksums, fault-schedule state) publish atomically via
``repro.checkpoint.store``, and ``oocsort(resume_from=...)`` replays from
the last completed round byte-identically to an uninterrupted run.  A
detected corruption restores from the last checkpoint and continues.

Transfer accounting (§5, the table in ``repro.kernels``'s docstring): in the
device-resident regime every key crosses the host link exactly twice; in the
spill regime the chunk phase still crosses twice (staged up overlapped with
compute, runs gathered down overlapped with the next sort) and every spilled
merge round adds one up + one down crossing per key — ``2·N·b·(1 +
rounds_spilled)`` total, with leftover single-run groups carried host-side
for free.  Failed transfer attempts re-cross the link: their bytes are kept
out of the clean per-phase formulas and reported separately as
``OocStats.retry_link_bytes`` (so ``h2d + d2h == chunk_link + spill_link +
retry_link`` stays exact).  ``OocStats`` reports the per-phase link bytes
and the driver's device high-water mark (``device_high_water_bytes``), the
gate that fails if anyone re-materialises full runs on device.

Determinism: the merge breaks ties by (key, run, position) — in both
regimes, with strip boundaries cutting the *same* merge path the device
partition would — so runs of equal keys keep chunk order and the output is a
pure function of the input stream and the chunking — byte-identical across
engines, regimes, slab sizes and merge fan-ins, certified by the oocsort
parity wall.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core import bijection, model
from repro.core.faults import (ChecksumError, FaultLedger, FaultPolicy,
                               RetriesExhausted, RetryPolicy, guarded,
                               tree_checksums)
from repro.core.hybrid import hybrid_sort
from repro.core.ranks import resolve_engine
from repro.kernels import merge as kmerge
from repro.kernels.fused import pad_length

# Modeled peak device working set, in units of one chunk / one slab payload.
# Chunk phase: staged chunks i and i+1, the sort's ping-pong pair (2x), the
# sorted run i, plus (spill) run i-1 with its working set still in flight —
# the ledger peaks under 10x one chunk's bytes, and the spill clamp starts
# from this reservation (then checks the engine-aware model below):
_CHUNK_FOOTPRINT = 12


def _chunk_working_bytes(chunk_elems: int, elem_bytes: int, cfg, engine,
                         key_dtype) -> int:
    """Modeled device working set of one chunk sort (its ping-pong pair).

    The kernel engine's donated ping-pong buffers are ``pad_length(n, kpb)``
    long — whole KPB tiles plus a spare — which dwarfs the raw chunk bytes
    for small chunks under a large ``kpb``; the jnp engines work in n-sized
    buffers.  Mirrors ``hybrid_sort``'s cfg/engine resolution so the spill
    budget clamp and the ledger charge what the sort actually allocates.
    """
    if resolve_engine(engine) == "kernel":
        kpb = (cfg or model.default_config(
            bijection.key_bits(key_dtype) // 8)).kpb
        return 2 * pad_length(chunk_elems, kpb) * elem_bytes
    return 2 * chunk_elems * elem_bytes


def _chunk_peak_bytes(chunk_elems: int, elem_bytes: int, cfg, engine,
                      key_dtype) -> int:
    """Modeled chunk-phase peak: staged chunks i-1/i/i+1, sorted runs i-1/i,
    and two sort working sets in flight (the spill pipeline's worst case)."""
    return 5 * chunk_elems * elem_bytes + 2 * _chunk_working_bytes(
        chunk_elems, elem_bytes, cfg, engine, key_dtype)
# Spill merge phase: padded source slabs and alternate slabs for strips i-1,
# i and i+1 plus one exact upload in transit — under 7x one padded slab; the
# slab derivation starts from this reservation and then shrinks the slab
# until the modeled worst case (_spill_peak_bytes, which also counts the
# pad tile and the scalar-prefetch tables the reservation alone misses at
# small budgets) provably fits:
_SLAB_FOOTPRINT = 10


def _spill_peak_bytes(slab: int, tile: int, elem_bytes: int,
                      kway: int) -> int:
    """Modeled worst-case live device bytes of the strip stream.

    Dominates the ledger's peak: at most 5 padded slabs live at once (source
    and alternate for strips i-1 and i, plus strip i+1's source) — modeled
    as 6 — plus strip i+1's exact upload still in transit and three strips'
    scalar-prefetch table sets.
    """
    bufsize = slab + tile                       # pad_length for tile-aligned
    g = slab // tile
    table_bytes = (2 * g + 2 * g * kway) * np.dtype(np.int32).itemsize
    return (6 * bufsize + slab) * elem_bytes + 3 * table_bytes


class OocStats(NamedTuple):
    num_chunks: int      # sorted device runs the input was split into
    merge_rounds: int    # merge-kernel rounds executed (this process)
    chunk_elems: int     # device chunk capacity the plan used (post-ladder)
    h2d_bytes: int       # host->device payload bytes (incl. failed attempts)
    d2h_bytes: int       # device->host payload bytes (incl. failed attempts)
    device_high_water_bytes: int = 0   # driver's modeled peak device bytes
    chunk_link_bytes: int = 0   # chunk-phase crossings: 2·N·(b+v)
    spill_link_bytes: int = 0   # spill-round crossings: +2·N·(b+v) per round
    rounds_spilled: int = 0     # rounds streamed through host-side runs
    spill_slab_elems: int = 0   # device slab capacity (0: device-resident)
    retries: int = 0            # guarded ops re-attempted after a fault
    faults_injected: int = 0    # faults the FaultPolicy fired (all kinds)
    degradations: int = 0       # ladder rungs walked (slab/kway/re-chunk)
    checksum_failures: int = 0  # host-buffer corruptions detected
    rounds_checkpointed: int = 0  # merge rounds published to the store
    retry_link_bytes: int = 0   # extra link bytes of failed/aborted attempts
    chunk_passes_executed: int = 0  # counting passes the chunk sorts ran
                                    # (entropy-adaptive: <= nominal ⌈k/d⌉
                                    # per chunk; 0 on resumed runs — the
                                    # chunk phase ran in another process)


class _DeviceLedger:
    """Driver-side model of live device bytes (the high-water gate).

    Tracks the buffers the oocsort driver itself stages, allocates and
    releases (chunks, sort working sets, runs, slabs); the high-water mark is
    what the spill regression test pins under ``spill_budget_bytes``, so any
    change that re-materialises O(N) on device blows it up.  Recovery resets
    ``live`` to the pre-attempt level (an aborted attempt's buffers drop)
    while ``high`` keeps the true peak.
    """

    def __init__(self):
        self.live = 0
        self.high = 0

    def alloc(self, nbytes: int) -> None:
        self.live += int(nbytes)
        self.high = max(self.high, self.live)

    def free(self, nbytes: int) -> None:
        self.live -= int(nbytes)


def _as_stream(reader, values):
    """Normalise the input to a stream of (keys, values-or-None) pieces."""
    if hasattr(reader, "shape") and hasattr(reader, "dtype"):
        yield reader, values
        return
    if values is not None:
        raise ValueError("with an iterator reader, pass values inline as "
                         "(keys, values) tuples")
    for item in reader:
        if isinstance(item, tuple):
            yield item
        else:
            yield item, None


def _rechunk(stream, chunk_elems: int):
    """Re-cut a stream of (keys, values) pieces into device-sized chunks.

    Returns ``(chunks, treedef, key_dtype, empty_leaves)`` where each chunk
    is ``(keys, value_leaves)`` with ``len(keys) <= chunk_elems`` (only the
    last chunk may be short) and ``empty_leaves`` are zero-length prototypes
    of the value leaves.  Host-side only: pieces are numpy views/copies.
    Validation errors name the offending input chunk index so a bad piece
    deep inside a stream is findable.
    """
    buf_k, buf_v = [], []
    chunks = []
    treedef = None
    key_dtype = None
    empty_leaves = ()
    pending = 0

    def emit(upto):
        nonlocal buf_k, buf_v, pending
        k = np.concatenate(buf_k) if len(buf_k) > 1 else buf_k[0]
        vs = [np.concatenate(c) if len(c) > 1 else c[0] for c in buf_v]
        chunks.append((k[:upto], tuple(v[:upto] for v in vs)))
        buf_k = [k[upto:]] if upto < k.shape[0] else []
        buf_v = [[v[upto:]] for v in vs] if upto < k.shape[0] else \
            [[] for _ in vs]
        pending -= upto

    for ci, (keys, vals) in enumerate(stream):
        keys = np.asarray(keys)
        if keys.ndim != 1:
            raise ValueError(f"chunk {ci}: oocsort expects 1-D key chunks")
        leaves, td = jax.tree.flatten(vals)
        leaves = [np.asarray(v) for v in leaves]
        if treedef is None:
            treedef, key_dtype = td, keys.dtype
            empty_leaves = tuple(v[:0] for v in leaves)
            buf_v = [[] for _ in leaves]
        elif td != treedef:
            raise ValueError(f"chunk {ci}: inconsistent value structure "
                             f"across chunks ({td} vs {treedef})")
        if keys.dtype != key_dtype:
            raise ValueError(f"chunk {ci}: inconsistent key dtype across "
                             f"chunks: {keys.dtype} vs {key_dtype}")
        if any(v.dtype != p.dtype for v, p in zip(leaves, empty_leaves)):
            raise ValueError(f"chunk {ci}: inconsistent value dtypes across "
                             f"chunks")
        if any(v.ndim != 1 for v in leaves):
            raise ValueError(f"chunk {ci}: oocsort value leaves must be 1-D "
                             f"(the merge kernel moves flat per-key slabs)")
        if any(v.shape[0] != keys.shape[0] for v in leaves):
            raise ValueError(f"chunk {ci}: value leaves must match the key "
                             f"length")
        if keys.shape[0] == 0:
            continue
        buf_k.append(keys)
        for c, v in zip(buf_v, leaves):
            c.append(v)
        pending += keys.shape[0]
        while pending >= chunk_elems:
            emit(chunk_elems)
    if pending:
        emit(pending)
    return chunks, treedef, key_dtype, empty_leaves


def _split_chunks(chunks, chunk_elems: int):
    """Re-split host chunks to a smaller capacity (budget clamp / ladder)."""
    out = []
    for k, vs in chunks:
        for o in range(0, k.shape[0], chunk_elems):
            out.append((k[o:o + chunk_elems],
                        tuple(v[o:o + chunk_elems] for v in vs)))
    return out


def _chunk_nbytes(chunk) -> int:
    return chunk[0].nbytes + sum(v.nbytes for v in chunk[1])


@functools.partial(jax.jit, static_argnames=("cfg", "engine", "interpret"))
def _sort_chunk(keys, leaves, cfg, engine, interpret):
    """Sort one staged chunk; emit the run as order-preserving unsigned bits.

    The trailing element is the executed counting-pass count of the chunk
    sort (entropy-adaptive schedules execute <= the nominal ⌈k/d⌉).
    """
    if leaves:
        sk, sv, st = hybrid_sort(keys, leaves, cfg=cfg, engine=engine,
                                 interpret=interpret, return_stats=True)
    else:
        sk, st = hybrid_sort(keys, cfg=cfg, engine=engine,
                             interpret=interpret, return_stats=True)
        sv = ()
    return bijection.to_ordered_bits(sk), sv, st.counting_passes


@functools.partial(jax.jit, static_argnames=("lens", "kway", "tile", "n",
                                             "interpret"),
                   donate_argnums=(2, 3))
def merge_round(src_keys, src_vals, alt_keys, alt_vals, *, lens, kway: int,
                tile: int, n: int, interpret: bool = True):
    """One k-way merge round: diagonal partition + ONE merge-kernel launch.

    ``lens`` is the static tuple of current run lengths; groups of up to
    ``kway`` adjacent runs merge into one run each.  The partition tables are
    sort-free binary searches; the data movement is the single
    ``kway_merge_round`` launch (the per-round census gate).  The alternate
    buffers are donated.
    """
    tables = kmerge.merge_path_partition(src_keys, lens, kway, tile)
    return kmerge.kway_merge_round(src_keys, src_vals, alt_keys, alt_vals,
                                   *tables, kway=kway, tpb=tile, n=n,
                                   interpret=interpret)


class _Job(NamedTuple):
    """One slab strip of one merge group, with its host source/target runs."""
    strip: kmerge.SpillStrip
    kruns: list           # host key runs of the group (np, unsigned bits)
    vruns: list           # host value runs: per run a tuple of leaves
    mk: np.ndarray        # merged host key run being assembled
    mv: Tuple[np.ndarray, ...]


class _RechunkEscalation(Exception):
    """The merge ladder's last rung: restart the pipeline with smaller chunks.

    Raised by the spill merge loop once slab and kway are already at their
    floors; the driver's outer attempt loop catches it, halves
    ``chunk_elems``, re-splits the host chunks and reruns.  Carries the
    :class:`RetriesExhausted` that exhausted the ladder for re-raising when
    re-chunking is impossible (min chunk size, or a resumed run with no
    chunks to re-split).
    """

    def __init__(self, cause: RetriesExhausted):
        super().__init__(str(cause))
        self.cause = cause


def _verify_runs(keys_h, vals_h, checksums) -> None:
    """Verify every host run against its recorded checksums (pre-consume)."""
    for i, (k, vs) in enumerate(zip(keys_h, vals_h)):
        if tree_checksums((k,) + tuple(vs)) != tuple(checksums[i]):
            raise ChecksumError(
                f"host run {i} no longer matches its recorded checksum "
                f"(corrupted while host-resident or in transit)")


def _run_checksums(keys_h, vals_h):
    return [tree_checksums((k,) + tuple(vs))
            for k, vs in zip(keys_h, vals_h)]


def _flat_run_arrays(keys_h, vals_h):
    out = list(keys_h)
    for vs in vals_h:
        out.extend(vs)
    return out


# --------------------- round-granular checkpointing -------------------------

def _dictkey(keystr: str) -> str:
    # jax keystr for a dict entry is "['name']"
    return keystr[2:-2]


def _save_round_checkpoint(directory: str, round_idx: int, keys_h, vals_h,
                           checksums, meta: dict, keep: int = 3) -> None:
    """Publish one merge round atomically via ``repro.checkpoint.store``.

    The tree is a flat dict — run key buffers ``k####``, value leaves
    ``v####_#`` and a JSON ``meta`` leaf (round index, merge plan, run
    lengths, per-run checksums, fault-schedule state) — so a resuming
    process can rebuild everything via ``store.restore_blind`` with no live
    pytree to mirror.  Host crossings: zero — the runs already live
    host-side in the spill regime; the cost is disk only.
    """
    meta = dict(meta, round=round_idx,
                run_lens=[int(k.shape[0]) for k in keys_h],
                checksums=[list(cs) for cs in checksums])
    tree = {"meta": np.frombuffer(json.dumps(meta).encode(), np.uint8)}
    for i, k in enumerate(keys_h):
        tree[f"k{i:04d}"] = k
        for j, v in enumerate(vals_h[i]):
            tree[f"v{i:04d}_{j}"] = v
    store.save_checkpoint(directory, round_idx, tree, keep=keep)


def _load_round_checkpoint(directory: str, round_idx: Optional[int] = None):
    """Load the newest (or a specific) checkpointed round.

    Returns ``(meta, keys_h, vals_h)`` with writable host arrays, after
    re-verifying the oocsort-level checksums on top of the store's own
    content hashes.
    """
    if round_idx is None:
        round_idx = store.latest_step(directory)
        if round_idx is None:
            raise ValueError(f"resume_from={directory!r}: no checkpointed "
                             f"rounds found")
    flat = {_dictkey(p): a
            for p, a in store.restore_blind(directory, round_idx).items()}
    meta = json.loads(bytes(flat.pop("meta")))
    nruns = len(meta["run_lens"])
    nleaves = meta["num_leaves"]
    keys_h = [np.array(flat[f"k{i:04d}"]) for i in range(nruns)]
    vals_h = [tuple(np.array(flat[f"v{i:04d}_{j}"]) for j in range(nleaves))
              for i in range(nruns)]
    _verify_runs(keys_h, vals_h, meta["checksums"])
    return meta, keys_h, vals_h


# --------------------- chunk phase ------------------------------------------

def _chunk_phase(chunks, *, spill, cfg, engine, interpret, key_dtype,
                 elem_bytes, ledger, faults, retry, faultlog, acct,
                 make_writable):
    """Double-buffered chunk staging + sorts, §5's upload/sort overlap.

    Every ``device_put`` goes through the ``chunk_upload`` fault site, every
    sort through ``sort_launch``, and (spill regime) every run download
    through ``run_download``.  ``acct`` accumulates the phase's clean link
    bytes so an aborted attempt can fold them into the retry ledger.
    Returns ``(runs, passes)``: the runs — device-resident ``(keys,
    leaves)`` pairs, or host numpy pairs in the spill regime — plus the
    per-chunk executed counting-pass counts (device scalars).
    """
    num_chunks = len(chunks)

    def upload(chunk, nbytes):
        out = guarded("chunk_upload", jax.device_put, chunk, policy=faults,
                      retry=retry, ledger=faultlog, cost_bytes=nbytes,
                      direction="h2d")
        ledger.alloc(nbytes)
        acct["up"] += nbytes
        return out

    def land(p):
        run, nbytes, held = p

        def download():
            k = np.asarray(run[0])
            vs = tuple(np.asarray(v) for v in run[1])
            if make_writable:
                k = k if k.flags.writeable else np.array(k)
                vs = tuple(v if v.flags.writeable else np.array(v)
                           for v in vs)
            return k, vs

        out = guarded("run_download", download, policy=faults, retry=retry,
                      ledger=faultlog, cost_bytes=nbytes, direction="d2h")
        acct["down"] += nbytes
        ledger.free(held)
        return out

    staged_bytes = _chunk_nbytes(chunks[0])
    staged = upload(chunks[0], staged_bytes)
    runs = []
    passes = []
    pending = None     # spill: (device run, run bytes, working bytes) to D2H
    for i in range(num_chunks):
        nxt = nxt_bytes = None
        if i + 1 < num_chunks:
            nxt_bytes = _chunk_nbytes(chunks[i + 1])
            nxt = upload(chunks[i + 1], nxt_bytes)       # stage i+1 ...
        ws = _chunk_working_bytes(chunks[i][0].shape[0], elem_bytes, cfg,
                                  engine, key_dtype)
        ledger.alloc(ws)                                 # sort ping-pong model
        run = guarded("sort_launch", _sort_chunk, *staged, cfg, engine,
                      interpret, policy=faults, retry=retry,
                      ledger=faultlog)                   # ... sort i
        passes.append(run[2])
        run = run[:2]
        ledger.alloc(staged_bytes)                       # the sorted run
        if spill:
            if pending is not None:                      # ... download run i-1
                runs.append(land(pending))
            pending = (run, staged_bytes, 2 * staged_bytes + ws)
        else:
            runs.append(run)
            ledger.free(staged_bytes + ws)               # staged + working set
        staged, staged_bytes = nxt, nxt_bytes
    if spill:
        runs.append(land(pending))
    return runs, passes


# --------------------- host-spill streaming merge ---------------------------

def _spill_round(keys_h, vals_h, *, kway: int, tile: int, slab: int,
                 interpret: bool, ledger: _DeviceLedger, faults, retry,
                 faultlog: FaultLedger, elem_bytes: int, acct: dict):
    """ONE host-spilled merge round: stream every group through device slabs.

    ``keys_h``/``vals_h`` are the host-resident sorted runs (unsigned bits).
    The round plans slab-sized strips for every multi-run group (single-run
    leftovers carry over host-side for free), then streams the strip list
    with the chunk phase's double-buffering discipline extended with D2H:
    strip i+1's upload and strip i−1's download are in flight while strip
    i's ``kway_merge_round`` launch runs.  Strip uploads, merge launches
    and strip downloads are guarded fault sites; ``acct`` accumulates the
    round's clean link bytes so an aborted round folds into the retry
    ledger.  Returns the next round's ``(keys, values)`` run lists; the
    device footprint never exceeds a handful of slabs (see
    ``_SLAB_FOOTPRINT``), which is what makes the §5 beyond-device-memory
    claim literal.
    """
    udtype = keys_h[0].dtype
    sentinel = udtype.type(~np.zeros((), udtype))
    bufsize = pad_length(slab, tile)

    next_k, next_v, jobs = [], [], []
    for grp in kmerge.merge_groups(list(range(len(keys_h))), kway):
        if len(grp) == 1:               # leftover run: carried for free
            next_k.append(keys_h[grp[0]])
            next_v.append(vals_h[grp[0]])
            continue
        kruns = [keys_h[j] for j in grp]
        vruns = [vals_h[j] for j in grp]
        glen = sum(r.shape[0] for r in kruns)
        mk = np.empty(glen, udtype)
        mv = tuple(np.empty(glen, v.dtype) for v in vruns[0])
        next_k.append(mk)
        next_v.append(mv)
        for strip in kmerge.spill_group_plan(kruns, kway, tile, slab):
            jobs.append(_Job(strip, kruns, vruns, mk, mv))

    def stage(job):
        strip, kruns, vruns = job.strip, job.kruns, job.vruns
        K = len(kruns)
        wins = [slice(strip.win_lo[r], strip.win_lo[r] + strip.win_len[r])
                for r in range(K)]
        up_k = np.concatenate([kruns[r][wins[r]] for r in range(K)])
        up_v = tuple(np.concatenate([vruns[r][li][wins[r]]
                                     for r in range(K)])
                     for li in range(len(vruns[0])))
        up_bytes = up_k.nbytes + sum(v.nbytes for v in up_v)

        def upload():
            dk = jax.device_put(up_k)
            dv = tuple(jax.device_put(v) for v in up_v)
            ts = tuple(jnp.asarray(t) for t in strip.tables)
            return dk, dv, ts

        dev_k, dev_v, tabs = guarded("slab_upload", upload, policy=faults,
                                     retry=retry, ledger=faultlog,
                                     cost_bytes=up_bytes, direction="h2d")
        ledger.alloc(up_bytes)
        acct["up"] += up_bytes
        tab_bytes = sum(t.nbytes for t in strip.tables)
        ledger.alloc(tab_bytes)
        # pad the exact upload out to the fixed slab (sentinel keys, zero
        # values) so every strip of a round shares one kernel signature
        pad = bufsize - strip.out_len
        slab_k = jnp.concatenate([dev_k, jnp.full((pad,), sentinel, udtype)])
        slab_v = tuple(jnp.concatenate([v, jnp.zeros((pad,), v.dtype)])
                       for v in dev_v)
        slab_bytes = slab_k.nbytes + sum(v.nbytes for v in slab_v)
        ledger.alloc(slab_bytes)
        ledger.free(up_bytes)
        return slab_k, slab_v, tabs, slab_bytes + tab_bytes

    def launch(staged):
        slab_k, slab_v, tabs, held = staged

        def fire():
            alt_k = jnp.full((bufsize,), sentinel, udtype)
            alt_v = tuple(jnp.zeros((bufsize,), v.dtype) for v in slab_v)
            ab = alt_k.nbytes + sum(v.nbytes for v in alt_v)
            return kmerge.kway_merge_round(
                slab_k, slab_v, alt_k, alt_v, *tabs, kway=kway, tpb=tile,
                n=slab, interpret=interpret), ab

        (out_k, out_v), alt_bytes = guarded(
            "merge_launch", fire, policy=faults, retry=retry, ledger=faultlog)
        ledger.alloc(alt_bytes)
        return out_k, out_v, held + alt_bytes

    def collect(launched, job):
        out_k, out_v, held = launched
        lo, sl = job.strip.out_lo, job.strip.out_len

        def download():
            kb = np.asarray(out_k[:sl])
            return kb, [np.asarray(v[:sl]) for v in out_v]

        kb, vbs = guarded("slab_download", download, policy=faults,
                          retry=retry, ledger=faultlog,
                          cost_bytes=sl * elem_bytes, direction="d2h")
        job.mk[lo:lo + sl] = kb
        down = kb.nbytes
        for li, vb in enumerate(vbs):
            job.mv[li][lo:lo + sl] = vb
            down += vb.nbytes
        acct["down"] += down
        ledger.free(held)

    staged = stage(jobs[0])
    prev = None
    for i, job in enumerate(jobs):
        nxt = stage(jobs[i + 1]) if i + 1 < len(jobs) else None      # up i+1
        launched = launch(staged)                                    # run i
        if prev is not None:
            collect(*prev)                                           # down i-1
        prev = (launched, job)
        staged = nxt
    collect(*prev)
    return next_k, next_v


def _merge_spilled(keys_h, vals_h, *, round_idx: int, kway: int, tile: int,
                   slab: int, budget: Optional[int], elem_bytes: int,
                   interpret: bool, ledger: _DeviceLedger, faults, retry,
                   faultlog: FaultLedger, checkpoint_dir: Optional[str],
                   checkpoint_every: int, meta_base: dict,
                   checksums=None, save_incoming: bool = True,
                   checksummed: bool = True):
    """The spill merge's round loop: verify → merge → checksum → checkpoint.

    Owns the merge half of the degradation ladder (slab halving to the
    ``tile`` floor, then kway halving to 2 — both output-byte-preserving;
    the re-chunk rung escalates via :class:`_RechunkEscalation`) and the
    recovery path for detected host corruption (restore the last published
    round and continue).  Returns ``(keys, vals, rounds_done, up, down,
    kway, slab)``.
    """
    up_total = down_total = 0
    rounds_done = 0
    if checksums is None and checksummed:
        checksums = _run_checksums(keys_h, vals_h)
    last_ckpt = None

    def save(idx):
        nonlocal last_ckpt
        _save_round_checkpoint(
            checkpoint_dir, idx, keys_h, vals_h, checksums,
            dict(meta_base, kway=kway, tile=tile, slab=slab,
                 fault_state=faults.state() if faults is not None else {}))
        faultlog.rounds_checkpointed += 1
        last_ckpt = idx

    if checkpoint_dir is not None:
        if save_incoming:
            save(round_idx)        # round-0 / adopted-state checkpoint
        else:
            last_ckpt = round_idx  # resumed from this very round
    if faults is not None and len(keys_h) > 1:
        faults.maybe_corrupt(_flat_run_arrays(keys_h, vals_h))

    while len(keys_h) > 1:
        live0 = ledger.live
        acct = {"up": 0, "down": 0}
        try:
            if checksummed:
                _verify_runs(keys_h, vals_h, checksums)
            nk, nv = _spill_round(
                keys_h, vals_h, kway=kway, tile=tile, slab=slab,
                interpret=interpret, ledger=ledger, faults=faults,
                retry=retry, faultlog=faultlog, elem_bytes=elem_bytes,
                acct=acct)
        except ChecksumError:
            faultlog.checksum_failures += 1
            ledger.live = live0
            faultlog.retry_h2d_bytes += acct["up"]
            faultlog.retry_d2h_bytes += acct["down"]
            if last_ckpt is None:
                raise
            meta, keys_h, vals_h = _load_round_checkpoint(
                checkpoint_dir, last_ckpt)
            checksums = [tuple(cs) for cs in meta["checksums"]]
            continue
        except RetriesExhausted as e:
            ledger.live = live0
            faultlog.retry_h2d_bytes += acct["up"]
            faultlog.retry_d2h_bytes += acct["down"]
            if slab > tile:                       # rung 1: halve the slab
                slab = max(tile, (slab // 2) - ((slab // 2) % tile))
                assert budget is None or _spill_peak_bytes(
                    slab, tile, elem_bytes, kway) <= budget
            elif kway > 2:                        # rung 2: halve the fan-in
                kway = max(2, kway // 2)
            else:                                 # rung 3: re-chunk smaller
                raise _RechunkEscalation(e)
            faultlog.degradations += 1
            continue
        up_total += acct["up"]
        down_total += acct["down"]
        keys_h, vals_h = nk, nv
        round_idx += 1
        rounds_done += 1
        if checksummed:
            checksums = _run_checksums(keys_h, vals_h)
        if checkpoint_dir is not None and len(keys_h) > 1 and \
                round_idx % checkpoint_every == 0:
            save(round_idx)
        if faults is not None and len(keys_h) > 1:
            faults.maybe_corrupt(_flat_run_arrays(keys_h, vals_h))
    return (keys_h[0], vals_h[0], rounds_done, up_total, down_total,
            kway, slab)


def oocsort(reader, chunk_elems: int, values: Any = None,
            cfg: Optional[model.SortConfig] = None,
            engine: Optional[str] = None, interpret: Optional[bool] = None,
            kway: int = 4, tile: int = 256, return_stats: bool = False,
            spill_budget_bytes: Optional[int] = None,
            device_slab_elems: Optional[int] = None,
            faults: Optional[FaultPolicy] = None,
            retry: Optional[RetryPolicy] = None,
            checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 1,
            resume_from: Optional[str] = None,
            values_like: Any = None,
            compress: bool = False):
    """Sort a host-resident array (or chunk stream) larger than one device run.

    ``reader`` is a 1-D numpy array, an iterable of 1-D key chunks (all of
    one dtype), or an iterable of ``(keys, values)`` chunk tuples;
    ``values`` (array-input only) is a 1-D array or pytree of 1-D arrays
    permuted alongside the keys (flat per-key slabs — the merge kernel's
    payload layout).  The
    input is cut into runs of ``chunk_elems`` keys; each run is sorted
    on-device by ``hybrid_sort`` (``cfg``/``engine`` as there) while the next
    chunk's ``jax.device_put`` is in flight, and the runs are merged by
    ⌈log_``kway``⌉ rounds of the merge-path kernel, one Pallas launch per
    round on donated ping-pong buffers.

    Setting ``spill_budget_bytes`` (a hard device-byte budget) and/or
    ``device_slab_elems`` (an explicit slab capacity, floored to a multiple
    of ``tile``) switches the merge phase to the **host-spill streaming**
    regime: runs live host-side between rounds and every round streams
    through a bounded handful of slab-sized device buffers (double-buffered
    uploads/downloads, one kernel launch per slab sweep; worst case modeled
    by ``_spill_peak_bytes``), so device memory stays bounded by the budget
    no matter how large the input.  When a budget is given,
    ``chunk_elems`` is clamped so the chunk phase fits it too, and the
    returned ``OocStats.device_high_water_bytes`` stays under it.

    Resilience (``core.faults``): pass ``faults`` (a deterministic
    :class:`FaultPolicy`) and ``retry`` (a :class:`RetryPolicy`, default 3
    bounded retries with capped backoff) to run every transfer and launch
    site through fault injection + retries; exhausted retries walk the
    degradation ladder (slab → kway → re-chunk) instead of crashing.  With
    any of ``faults``/``retry``/``checkpoint_dir`` set, host-resident runs
    are checksummed at each crossing and verified before consumption.
    ``checkpoint_dir`` (spill regime only) publishes the runs + a manifest
    after every ``checkpoint_every``-th merge round;
    ``oocsort(None, 0, resume_from=dir)`` resumes from the newest published
    round and replays to a byte-identical result, adopting the plan (kway/
    tile/slab/dtype) recorded in the manifest.  On resume, pass
    ``values_like`` (a structure prototype) to get the value pytree back in
    its original shape; otherwise a single value leaf is returned bare and
    multiple leaves as a tuple.

    ``compress=True`` packs the keys' live bits into the smallest unsigned
    carrier **host-side** before anything touches the device (bit positions
    constant across the whole input contribute no ordering information;
    ``core.bijection.CompressionPlan``).  Every downstream byte count —
    chunk uploads, sort ping-pong, merge slabs, spill budgets, checkpoint
    manifests — then moves b_eff-sized keys, and uint64 inputs with <= 32
    live bits sort without ``jax_enable_x64``.  The output is decoded back
    to the original dtype and is byte-identical to the uncompressed sort.

    Returns host numpy arrays: ``sorted_keys``, or ``(sorted_keys,
    permuted_values)`` when values were given; append an :class:`OocStats`
    when ``return_stats``.  Pair movement is consistent but — like
    ``hybrid_sort`` — not stable across equal keys *within* a chunk; across
    chunks the merge keeps run order (ties break by run index).
    """
    if checkpoint_every < 1:
        raise ValueError("checkpoint_every must be >= 1")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    faultlog = FaultLedger()
    ledger = _DeviceLedger()

    # --- resume: the checkpoint manifest is the plan -----------------------
    if resume_from is not None:
        return _resume(resume_from, spill_budget_bytes=spill_budget_bytes,
                       interpret=interpret, faults=faults, retry=retry,
                       checkpoint_dir=checkpoint_dir,
                       checkpoint_every=checkpoint_every,
                       values_like=values_like, return_stats=return_stats,
                       faultlog=faultlog, ledger=ledger)

    if chunk_elems < 1:
        raise ValueError("chunk_elems must be >= 1")
    if kway < 2:
        raise ValueError("kway must be >= 2")
    if tile < 8:
        raise ValueError("tile must be >= 8")
    spill = spill_budget_bytes is not None or device_slab_elems is not None
    if spill_budget_bytes is not None and spill_budget_bytes < 1:
        raise ValueError("spill_budget_bytes must be >= 1")
    if checkpoint_dir is not None and not spill:
        raise ValueError(
            "checkpoint_dir requires the host-spill regime (set "
            "spill_budget_bytes or device_slab_elems): round-granular "
            "checkpoints publish host-resident runs, which only exist there")

    chunks, treedef, key_dtype, empty_leaves = _rechunk(
        _as_stream(reader, values), chunk_elems)
    had_values = treedef is not None and treedef.num_leaves > 0

    def finish(keys_np, leaves_np, stats):
        out = (keys_np,) if not had_values else \
            (keys_np, jax.tree.unflatten(treedef, list(leaves_np)))
        if return_stats:
            out = out + (stats,)
        return out[0] if len(out) == 1 else out

    if key_dtype is None:
        raise ValueError("empty iterator reader: yield at least one "
                         "(possibly empty) chunk to fix the dtype")

    # --- compressed-key mode: pack live bits host-side ---------------------
    # The global OR/AND reduce over the ordered bits of every chunk finds the
    # bit positions that vary anywhere in the input; packing them out is
    # order-preserving (two distinct keys first differ at a live bit), so the
    # whole pipeline sorts/merges the packed carrier and only the two decode
    # sites below see the original dtype again.  Done before the spill plan
    # so elem_bytes, slab sizing and the chunk clamp all model packed keys.
    orig_key_dtype = key_dtype
    cplan = None
    if compress and chunks:
        bits = bijection.key_bits(key_dtype)
        orv, andv = 0, (1 << bits) - 1
        for ckeys, _ in chunks:
            ub = bijection.to_ordered_bits_np(ckeys)
            if ub.size:
                orv |= int(np.bitwise_or.reduce(ub))
                andv &= int(np.bitwise_and.reduce(ub))
        mask = orv ^ andv
        cplan = bijection.CompressionPlan(mask=mask, dead=andv & ~mask,
                                          source_bits=bits)
        chunks = [(bijection.pack_ordered_bits_np(
                       bijection.to_ordered_bits_np(ckeys), cplan), vs)
                  for ckeys, vs in chunks]
        key_dtype = np.dtype(bijection.packed_carrier_dtype(cplan))

    def decode_np(ubits):
        if cplan is not None:
            ubits = bijection.unpack_ordered_bits_np(ubits, cplan)
        return bijection.from_ordered_bits_np(ubits, orig_key_dtype)

    # --- spill plan: slab capacity + chunk clamp from the device budget ----
    # (validated before the empty-input return so a misconfigured slab or
    # budget fails input-independently, not just on the first non-empty run)
    elem_bytes = np.dtype(key_dtype).itemsize + \
        sum(v.dtype.itemsize for v in empty_leaves)
    slab = 0
    if spill:
        slab = device_slab_elems
        if slab is not None:
            slab -= slab % tile
            if slab < tile:
                raise ValueError("device_slab_elems must be >= tile")
        if spill_budget_bytes is not None:
            # the real constraint is the modeled worst case (incl. pad tile
            # + descriptor tables, which matter at tight budgets): start
            # from the explicit slab, or the footprint reservation when
            # deriving, and shrink tile by tile until the peak provably fits
            if slab is None:
                slab = spill_budget_bytes // (_SLAB_FOOTPRINT * elem_bytes)
                slab -= slab % tile
            while slab >= tile and _spill_peak_bytes(
                    slab, tile, elem_bytes, kway) > spill_budget_bytes:
                slab -= tile
            if slab < tile:
                raise ValueError(
                    f"spill_budget_bytes={spill_budget_bytes} too small: "
                    f"need >= "
                    f"{_spill_peak_bytes(tile, tile, elem_bytes, kway)} "
                    f"for tile={tile} (worst-case stream of one-tile slabs)")
            # largest chunk whose engine-aware peak (kernel chunks allocate
            # pad_length(n, kpb)-sized ping-pong pairs) fits the budget
            peak = lambda c: _chunk_peak_bytes(c, elem_bytes, cfg, engine,
                                               key_dtype)
            if peak(1) > spill_budget_bytes:
                raise ValueError(
                    f"spill_budget_bytes={spill_budget_bytes} too small for "
                    f"the chunk phase: even a 1-element chunk sort models "
                    f"{peak(1)} device bytes (engine "
                    f"{resolve_engine(engine)!r}; the kernel engine pads to "
                    f"whole cfg.kpb tiles — pass a smaller-kpb cfg)")
            lo = 1
            hi = max(1, spill_budget_bytes // (_CHUNK_FOOTPRINT * elem_bytes))
            while peak(hi) <= spill_budget_bytes and hi < chunk_elems:
                hi = min(2 * hi, chunk_elems)    # the reservation start is
                # conservative for the jnp engines; grow to the model's edge
            while lo < hi:
                mid = (lo + hi + 1) // 2
                lo, hi = (mid, hi) if peak(mid) <= spill_budget_bytes \
                    else (lo, mid - 1)
            if lo < chunk_elems:
                chunk_elems = lo
                chunks = _split_chunks(chunks, chunk_elems)

    if not chunks:
        stats = OocStats(0, 0, chunk_elems, 0, 0,
                         spill_slab_elems=slab if spill else 0)
        return finish(np.empty((0,), key_dtype), empty_leaves, stats)

    k = bijection.key_bits(key_dtype)
    if k > 32 and not jax.config.jax_enable_x64:
        raise RuntimeError("64-bit keys require jax_enable_x64")
    if not jax.config.jax_enable_x64:
        # leaf dtypes are chunk-uniform (_rechunk), so one chunk decides;
        # device_put would otherwise silently truncate 64-bit payloads
        for v in chunks[0][1]:
            if v.dtype.itemsize > 4:
                raise RuntimeError(
                    f"64-bit value leaves ({v.dtype}) require "
                    "jax_enable_x64")

    n = sum(c[0].shape[0] for c in chunks)
    make_writable = faults is not None and faults.corrupts
    meta_base = {"key_dtype": np.dtype(key_dtype).str, "n": n,
                 "num_leaves": len(empty_leaves),
                 "value_dtypes": [v.dtype.str for v in empty_leaves]}
    if cplan is not None:
        # manifest runs hold PACKED bits; record the plan so resume decodes
        meta_base["compress"] = {"mask": cplan.mask, "dead": cplan.dead,
                                 "source_bits": cplan.source_bits,
                                 "orig_dtype": np.dtype(orig_key_dtype).str}

    # --- attempt loop: the degradation ladder's restart point --------------
    # Each attempt runs the chunk phase and the merge phase under the current
    # (chunk_elems, kway, slab) plan.  Merge-internal rungs (slab, kway) are
    # walked inside _merge_spilled without restarting; chunk-phase failures
    # and the ladder's re-chunk rung land here and restart with smaller
    # chunks (ledger.live resets, the high-water mark and fault counters
    # persist — the fault schedule never replays).
    while True:
        ledger.live = 0
        num_chunks = len(chunks)
        lens = [c[0].shape[0] for c in chunks]
        acct = {"up": 0, "down": 0}

        def _abort_attempt():
            ledger.live = 0
            faultlog.retry_h2d_bytes += acct["up"]
            faultlog.retry_d2h_bytes += acct["down"]

        def _rechunk_smaller():
            nonlocal chunk_elems, chunks
            if chunk_elems <= 1:
                return False
            chunk_elems = max(1, chunk_elems // 2)
            chunks = _split_chunks(chunks, chunk_elems)
            faultlog.degradations += 1
            return True

        # --- chunk phase: double-buffered staging --------------------------
        try:
            runs, cpasses = _chunk_phase(
                chunks, spill=spill, cfg=cfg, engine=engine,
                interpret=interpret, key_dtype=key_dtype,
                elem_bytes=elem_bytes, ledger=ledger, faults=faults,
                retry=retry, faultlog=faultlog, acct=acct,
                make_writable=make_writable)
        except RetriesExhausted:
            _abort_attempt()
            if not _rechunk_smaller():
                raise
            continue
        chunk_up, chunk_down = acct["up"], acct["down"]

        # --- merge phase ----------------------------------------------------
        rounds = 0
        spill_up = spill_down = 0
        if spill:
            meta = dict(meta_base, num_chunks=num_chunks,
                        chunk_elems=chunk_elems)
            try:
                if num_chunks == 1:
                    keys_h, vals_h = runs[0]
                else:
                    (keys_h, vals_h, rounds, spill_up, spill_down, kway,
                     slab) = _merge_spilled(
                        [r[0] for r in runs], [r[1] for r in runs],
                        round_idx=0, kway=kway, tile=tile, slab=slab,
                        budget=spill_budget_bytes, elem_bytes=elem_bytes,
                        interpret=interpret, ledger=ledger, faults=faults,
                        retry=retry, faultlog=faultlog,
                        checkpoint_dir=checkpoint_dir,
                        checkpoint_every=checkpoint_every,
                        meta_base=meta,
                        checksummed=(faults is not None or retry is not None
                                     or checkpoint_dir is not None))
            except _RechunkEscalation as esc:
                _abort_attempt()
                if not _rechunk_smaller():
                    raise esc.cause
                continue
            keys_np = decode_np(keys_h)
            leaves_np = tuple(vals_h)
        else:
            try:
                if num_chunks == 1:
                    ck, cv = runs[0]     # single run: no marshalling/merge
                else:
                    # the padded current/alternate buffers follow
                    # fused.make_ping_pong's contract (sentinel key pad, zero
                    # value pad), built inline so run marshalling is a single
                    # concatenate — one fewer sweep than padding a
                    # pre-concatenated copy
                    udtype = runs[0][0].dtype
                    n_pad = pad_length(n, tile)
                    sentinel = ~jnp.zeros((), udtype)
                    ck = jnp.concatenate(
                        [r[0] for r in runs] +
                        [jnp.full((n_pad - n,), sentinel, udtype)])
                    num_leaves = len(runs[0][1])
                    cv = tuple(
                        jnp.concatenate(
                            [r[1][i] for r in runs] +
                            [jnp.zeros((n_pad - n,), runs[0][1][i].dtype)])
                        for i in range(num_leaves))
                    av = tuple(jnp.zeros_like(v) for v in cv)
                    ledger.alloc(2 * n_pad * elem_bytes)  # flat ping-pong pair
                    ledger.free(n * elem_bytes)   # per-run buffers release
                    del runs    # the merge phase's footprint is the two
                    # flat ping-pong buffers only — the very footprint the
                    # spill regime replaces with bounded slabs (the host
                    # chunks stay live only while a fault policy may demand
                    # a re-chunk restart)
                    if faults is None:
                        del chunks
                    mlens = list(lens)
                    ak = jnp.full_like(ck, sentinel)
                    while len(mlens) > 1:
                        nk, nv = guarded(
                            "merge_launch", merge_round, ck, cv, ak, av,
                            policy=faults, retry=retry, ledger=faultlog,
                            lens=tuple(mlens), kway=kway, tile=tile, n=n,
                            interpret=interpret)
                        ak, av = ck, cv      # old current donates next round
                        ck, cv = nk, nv
                        mlens = [sum(g)
                                 for g in kmerge.merge_groups(mlens, kway)]
                        rounds += 1

                def gather():
                    if cplan is None:
                        kn = np.asarray(
                            bijection.from_ordered_bits(ck[:n], key_dtype))
                    else:
                        kn = decode_np(np.asarray(ck[:n]))
                    return kn, tuple(np.asarray(v[:n]) for v in cv)

                keys_np, leaves_np = guarded(
                    "run_download", gather, policy=faults, retry=retry,
                    ledger=faultlog, cost_bytes=n * elem_bytes,
                    direction="d2h")
                # the link carried the PACKED carrier; decode is host-side
                acct["down"] += n * np.dtype(key_dtype).itemsize + \
                    sum(v.nbytes for v in leaves_np)
                chunk_down = acct["down"]
            except RetriesExhausted:
                # non-spill recovery: the device runs were donated away, so
                # every rung restarts the attempt — kway first, then re-chunk
                _abort_attempt()
                if kway > 2:
                    kway = max(2, kway // 2)
                    faultlog.degradations += 1
                    continue
                if not _rechunk_smaller():
                    raise
                continue
        break

    h2d = chunk_up + spill_up + faultlog.retry_h2d_bytes
    d2h = chunk_down + spill_down + faultlog.retry_d2h_bytes
    stats = OocStats(
        len(lens), rounds, chunk_elems, h2d, d2h,
        device_high_water_bytes=ledger.high,
        chunk_link_bytes=chunk_up + chunk_down,
        spill_link_bytes=spill_up + spill_down,
        rounds_spilled=rounds if spill else 0,
        spill_slab_elems=slab,
        retries=faultlog.retries,
        faults_injected=faultlog.faults_injected,
        degradations=faultlog.degradations,
        checksum_failures=faultlog.checksum_failures,
        rounds_checkpointed=faultlog.rounds_checkpointed,
        retry_link_bytes=faultlog.retry_link_bytes,
        chunk_passes_executed=sum(int(p) for p in cpasses))
    return finish(keys_np, leaves_np, stats)


def _resume(resume_from: str, *, spill_budget_bytes, interpret, faults,
            retry, checkpoint_dir, checkpoint_every, values_like,
            return_stats, faultlog: FaultLedger, ledger: _DeviceLedger):
    """Replay an interrupted spill-merge from its newest published round.

    Adopts the plan recorded in the manifest (kway/tile/slab/key dtype) so
    the remaining rounds are byte-identical to the uninterrupted run's.
    Stats cover only the work done by this process (the chunk phase ran in
    the interrupted one).  Continued checkpointing: pass ``checkpoint_dir``
    — same directory to extend the existing sequence, a different one to
    re-publish the adopted state there first.
    """
    meta, keys_h, vals_h = _load_round_checkpoint(resume_from)
    kway, tile, slab = meta["kway"], meta["tile"], meta["slab"]
    key_dtype = np.dtype(meta["key_dtype"])    # packed carrier if compressed
    comp = meta.get("compress")
    cplan = None
    out_dtype = key_dtype
    if comp is not None:
        cplan = bijection.CompressionPlan(mask=int(comp["mask"]),
                                          dead=int(comp["dead"]),
                                          source_bits=int(comp["source_bits"]))
        out_dtype = np.dtype(comp["orig_dtype"])
    n = meta["n"]
    elem_bytes = key_dtype.itemsize + \
        sum(np.dtype(d).itemsize for d in meta["value_dtypes"])
    if spill_budget_bytes is not None and _spill_peak_bytes(
            slab, tile, elem_bytes, kway) > spill_budget_bytes:
        raise ValueError(
            f"resume_from plan (slab={slab}, kway={kway}, tile={tile}) "
            f"models a peak above spill_budget_bytes={spill_budget_bytes}; "
            f"resume with the original budget or none")
    if bijection.key_bits(key_dtype) > 32 and not jax.config.jax_enable_x64:
        raise RuntimeError("64-bit keys require jax_enable_x64")
    if faults is not None and meta.get("fault_state"):
        faults.load_state(meta["fault_state"])
    same_dir = checkpoint_dir is not None and \
        os.path.abspath(checkpoint_dir) == os.path.abspath(resume_from)
    try:
        keys_h0, vals_h0, rounds, up, down, kway, slab = _merge_spilled(
            keys_h, vals_h, round_idx=meta["round"], kway=kway, tile=tile,
            slab=slab, budget=spill_budget_bytes, elem_bytes=elem_bytes,
            interpret=interpret, ledger=ledger, faults=faults, retry=retry,
            faultlog=faultlog, checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every,
            meta_base={k: meta[k] for k in
                       ("key_dtype", "n", "num_leaves", "value_dtypes",
                        "num_chunks", "chunk_elems", "compress")
                       if k in meta},
            checksums=[tuple(cs) for cs in meta["checksums"]],
            save_incoming=not same_dir)
    except _RechunkEscalation as esc:
        raise esc.cause      # no host chunks to re-split in a resumed run

    if cplan is not None:
        keys_h0 = bijection.unpack_ordered_bits_np(keys_h0, cplan)
    keys_np = bijection.from_ordered_bits_np(keys_h0, out_dtype)
    leaves_np = tuple(vals_h0)
    nl = meta["num_leaves"]
    if nl == 0:
        out = (keys_np,)
    elif values_like is not None:
        td = jax.tree.flatten(values_like)[1]
        if td.num_leaves != nl:
            raise ValueError(f"values_like has {td.num_leaves} leaves; the "
                             f"checkpoint recorded {nl}")
        out = (keys_np, jax.tree.unflatten(td, list(leaves_np)))
    elif nl == 1:
        out = (keys_np, leaves_np[0])
    else:
        out = (keys_np, leaves_np)
    if return_stats:
        stats = OocStats(
            meta["num_chunks"], rounds, meta["chunk_elems"],
            up + faultlog.retry_h2d_bytes, down + faultlog.retry_d2h_bytes,
            device_high_water_bytes=ledger.high,
            chunk_link_bytes=0,
            spill_link_bytes=up + down,
            rounds_spilled=rounds,
            spill_slab_elems=slab,
            retries=faultlog.retries,
            faults_injected=faultlog.faults_injected,
            degradations=faultlog.degradations,
            checksum_failures=faultlog.checksum_failures,
            rounds_checkpointed=faultlog.rounds_checkpointed,
            retry_link_bytes=faultlog.retry_link_bytes)
        out = out + (stats,)
    return out[0] if len(out) == 1 else out


# --- contract declarations (verified by repro.analysis; see analysis/contracts)
# §5 census + transfer tables: a chunk sort inherits the hybrid contract at
# chunk size; a device merge round and a spill slab sweep are each ONE
# kway_merge_round launch moving exactly one read + one write sweep of the
# (pad_length-sized) run/slab buffer.
ANALYSIS_CONTRACTS = {
    "ooc_chunk_sort": {
        "entry": "repro.core.outofcore._sort_chunk",
        "census": {"launch_total": "2 + classes",
                   "while_body_launches": "[1]"},
        "sort_free": True,
        "donation": {"_fused_pass_kernel": "1 + vals"},
        "transfer": {
            "sweep_kernels": ["_hist_kernel", "_fused_pass_kernel"],
            "bytes": "(2 * passes + 1) * n_pad * kb"
                     " + 2 * passes * n_pad * vb",
        },
    },
    "ooc_merge_round": {
        "entry": "repro.core.outofcore.merge_round",
        "census": {"launch_total": "1", "while_body_launches": "[]"},
        "sort_free": True,
        "donation": {"_kway_merge_kernel": "1 + vals"},
        "transfer": {
            "sweep_kernels": ["_kway_merge_kernel"],
            "bytes": "2 * n_pad * kb + 2 * n_pad * vb",
        },
    },
    "ooc_slab_sweep": {
        "entry": "repro.kernels.merge.kway_merge_round",
        "census": {"launch_total": "1", "while_body_launches": "[]"},
        "sort_free": True,
        "donation": {"_kway_merge_kernel": "1 + vals"},
        "transfer": {
            "sweep_kernels": ["_kway_merge_kernel"],
            "bytes": "2 * n_pad * kb + 2 * n_pad * vb",
        },
    },
}
