"""Out-of-core pipelined sort: chunked device runs + streaming k-way merges.

The paper's second headline (§5, the 64 GB end-to-end result) sorts inputs
that exceed device memory with a chunk-sort-then-merge pipeline: the host
array streams to the device in chunks, every chunk is sorted on-device while
the next chunk's transfer is in flight, and the sorted runs are merged by a
device merge kernel.  ``oocsort`` is that pipeline in JAX terms, in two
device-memory regimes:

  1. the host-resident input (array or chunk iterator) is re-chunked into
     runs of ``chunk_elems`` keys (+ value slabs),
  2. chunk i+1 is staged with ``jax.device_put`` *before* chunk i's sort is
     consumed — JAX dispatch is asynchronous, so the upload and the sort
     overlap (double buffering, the §5 transfer/compute pipeline),
  3. each chunk is sorted by ``hybrid_sort`` — the fused single-launch
     counting-pass engine on donated ping-pong buffers (PR 1–2) — and mapped
     to order-preserving unsigned bits so runs merge bitwise,
  4. **device-resident merge** (default): ⌈log_K(runs)⌉ rounds of the
     merge-path kernel (``kernels.merge.kway_merge_round``) fuse K adjacent
     runs per group, ONE Pallas launch per round, ping-pong buffers donated
     between rounds — the whole flat run buffer lives on device,
  5. **host-spill streaming merge** (``spill_budget_bytes`` /
     ``device_slab_elems``): runs live *host-side* between rounds and every
     round streams its groups through a bounded handful of slab-sized
     device buffers (double-buffered sources, alternates and descriptor
     tables — ``_SLAB_FOOTPRINT`` models the worst case).  The
     merge-path co-ranks are computed from O(bits·K·log L) probed host
     elements per diagonal (``kernels.merge.host_coranks``), each group is cut into slab-sized
     strips of whole output tiles (``kernels.merge.spill_group_plan``), and
     strip i+1's ``device_put`` upload plus strip i−1's download are in
     flight while strip i's ``kway_merge_round`` launch runs — the chunk
     phase's double-buffering discipline extended with D2H, ONE Pallas
     launch per group-slab sweep.  Device memory stays bounded by the slab
     budget no matter how large the input,
  6. the merged keys map back to the key dtype and land on the host (the
     spill path inverts the bit bijection in numpy — no extra device trip).

Transfer accounting (§5, the table in ``repro.kernels``'s docstring): in the
device-resident regime every key crosses the host link exactly twice; in the
spill regime the chunk phase still crosses twice (staged up overlapped with
compute, runs gathered down overlapped with the next sort) and every spilled
merge round adds one up + one down crossing per key — ``2·N·b·(1 +
rounds_spilled)`` total, with leftover single-run groups carried host-side
for free.  ``OocStats`` reports the per-phase link bytes and the driver's
device high-water mark (``device_high_water_bytes``), the gate that fails if
anyone re-materialises full runs on device.

Determinism: the merge breaks ties by (key, run, position) — in both
regimes, with strip boundaries cutting the *same* merge path the device
partition would — so runs of equal keys keep chunk order and the output is a
pure function of the input stream and the chunking — byte-identical across
engines and regimes, certified by the oocsort parity wall.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bijection, model
from repro.core.hybrid import hybrid_sort
from repro.core.ranks import resolve_engine
from repro.kernels import merge as kmerge
from repro.kernels.fused import pad_length

# Modeled peak device working set, in units of one chunk / one slab payload.
# Chunk phase: staged chunks i and i+1, the sort's ping-pong pair (2x), the
# sorted run i, plus (spill) run i-1 with its working set still in flight —
# the ledger peaks under 10x one chunk's bytes, and the spill clamp starts
# from this reservation (then checks the engine-aware model below):
_CHUNK_FOOTPRINT = 12


def _chunk_working_bytes(chunk_elems: int, elem_bytes: int, cfg, engine,
                         key_dtype) -> int:
    """Modeled device working set of one chunk sort (its ping-pong pair).

    The kernel engine's donated ping-pong buffers are ``pad_length(n, kpb)``
    long — whole KPB tiles plus a spare — which dwarfs the raw chunk bytes
    for small chunks under a large ``kpb``; the jnp engines work in n-sized
    buffers.  Mirrors ``hybrid_sort``'s cfg/engine resolution so the spill
    budget clamp and the ledger charge what the sort actually allocates.
    """
    if resolve_engine(engine) == "kernel":
        kpb = (cfg or model.default_config(
            bijection.key_bits(key_dtype) // 8)).kpb
        return 2 * pad_length(chunk_elems, kpb) * elem_bytes
    return 2 * chunk_elems * elem_bytes


def _chunk_peak_bytes(chunk_elems: int, elem_bytes: int, cfg, engine,
                      key_dtype) -> int:
    """Modeled chunk-phase peak: staged chunks i-1/i/i+1, sorted runs i-1/i,
    and two sort working sets in flight (the spill pipeline's worst case)."""
    return 5 * chunk_elems * elem_bytes + 2 * _chunk_working_bytes(
        chunk_elems, elem_bytes, cfg, engine, key_dtype)
# Spill merge phase: padded source slabs and alternate slabs for strips i-1,
# i and i+1 plus one exact upload in transit — under 7x one padded slab; the
# slab derivation starts from this reservation and then shrinks the slab
# until the modeled worst case (_spill_peak_bytes, which also counts the
# pad tile and the scalar-prefetch tables the reservation alone misses at
# small budgets) provably fits:
_SLAB_FOOTPRINT = 10


def _spill_peak_bytes(slab: int, tile: int, elem_bytes: int,
                      kway: int) -> int:
    """Modeled worst-case live device bytes of the strip stream.

    Dominates the ledger's peak: at most 5 padded slabs live at once (source
    and alternate for strips i-1 and i, plus strip i+1's source) — modeled
    as 6 — plus strip i+1's exact upload still in transit and three strips'
    scalar-prefetch table sets.
    """
    bufsize = slab + tile                       # pad_length for tile-aligned
    g = slab // tile
    table_bytes = (2 * g + 2 * g * kway) * np.dtype(np.int32).itemsize
    return (6 * bufsize + slab) * elem_bytes + 3 * table_bytes


class OocStats(NamedTuple):
    num_chunks: int      # sorted device runs the input was split into
    merge_rounds: int    # ⌈log_kway(num_chunks)⌉ merge-kernel rounds
    chunk_elems: int     # device chunk capacity the plan used
    h2d_bytes: int       # host->device payload bytes (keys + values)
    d2h_bytes: int       # device->host payload bytes (keys + values)
    device_high_water_bytes: int = 0   # driver's modeled peak device bytes
    chunk_link_bytes: int = 0   # chunk-phase crossings: 2·N·(b+v)
    spill_link_bytes: int = 0   # spill-round crossings: +2·N·(b+v) per round
    rounds_spilled: int = 0     # rounds streamed through host-side runs
    spill_slab_elems: int = 0   # device slab capacity (0: device-resident)


class _DeviceLedger:
    """Driver-side model of live device bytes (the high-water gate).

    Tracks the buffers the oocsort driver itself stages, allocates and
    releases (chunks, sort working sets, runs, slabs); the high-water mark is
    what the spill regression test pins under ``spill_budget_bytes``, so any
    change that re-materialises O(N) on device blows it up.
    """

    def __init__(self):
        self.live = 0
        self.high = 0

    def alloc(self, nbytes: int) -> None:
        self.live += int(nbytes)
        self.high = max(self.high, self.live)

    def free(self, nbytes: int) -> None:
        self.live -= int(nbytes)


def _as_stream(reader, values):
    """Normalise the input to a stream of (keys, values-or-None) pieces."""
    if hasattr(reader, "shape") and hasattr(reader, "dtype"):
        yield reader, values
        return
    if values is not None:
        raise ValueError("with an iterator reader, pass values inline as "
                         "(keys, values) tuples")
    for item in reader:
        if isinstance(item, tuple):
            yield item
        else:
            yield item, None


def _rechunk(stream, chunk_elems: int):
    """Re-cut a stream of (keys, values) pieces into device-sized chunks.

    Returns ``(chunks, treedef, key_dtype, empty_leaves)`` where each chunk
    is ``(keys, value_leaves)`` with ``len(keys) <= chunk_elems`` (only the
    last chunk may be short) and ``empty_leaves`` are zero-length prototypes
    of the value leaves.  Host-side only: pieces are numpy views/copies.
    """
    buf_k, buf_v = [], []
    chunks = []
    treedef = None
    key_dtype = None
    empty_leaves = ()
    pending = 0

    def emit(upto):
        nonlocal buf_k, buf_v, pending
        k = np.concatenate(buf_k) if len(buf_k) > 1 else buf_k[0]
        vs = [np.concatenate(c) if len(c) > 1 else c[0] for c in buf_v]
        chunks.append((k[:upto], tuple(v[:upto] for v in vs)))
        buf_k = [k[upto:]] if upto < k.shape[0] else []
        buf_v = [[v[upto:]] for v in vs] if upto < k.shape[0] else \
            [[] for _ in vs]
        pending -= upto

    for keys, vals in stream:
        keys = np.asarray(keys)
        if keys.ndim != 1:
            raise ValueError("oocsort expects 1-D key chunks")
        leaves, td = jax.tree.flatten(vals)
        leaves = [np.asarray(v) for v in leaves]
        if treedef is None:
            treedef, key_dtype = td, keys.dtype
            empty_leaves = tuple(v[:0] for v in leaves)
            buf_v = [[] for _ in leaves]
        elif td != treedef:
            raise ValueError("inconsistent value structure across chunks")
        if keys.dtype != key_dtype:
            raise ValueError(f"inconsistent key dtype across chunks: "
                             f"{keys.dtype} vs {key_dtype}")
        if any(v.dtype != p.dtype for v, p in zip(leaves, empty_leaves)):
            raise ValueError("inconsistent value dtypes across chunks")
        if any(v.ndim != 1 for v in leaves):
            raise ValueError("oocsort value leaves must be 1-D (the merge "
                             "kernel moves flat per-key slabs)")
        if any(v.shape[0] != keys.shape[0] for v in leaves):
            raise ValueError("value leaves must match the key length")
        if keys.shape[0] == 0:
            continue
        buf_k.append(keys)
        for c, v in zip(buf_v, leaves):
            c.append(v)
        pending += keys.shape[0]
        while pending >= chunk_elems:
            emit(chunk_elems)
    if pending:
        emit(pending)
    return chunks, treedef, key_dtype, empty_leaves


def _split_chunks(chunks, chunk_elems: int):
    """Re-split host chunks to a smaller capacity (spill-budget clamp)."""
    out = []
    for k, vs in chunks:
        for o in range(0, k.shape[0], chunk_elems):
            out.append((k[o:o + chunk_elems],
                        tuple(v[o:o + chunk_elems] for v in vs)))
    return out


def _chunk_nbytes(chunk) -> int:
    return chunk[0].nbytes + sum(v.nbytes for v in chunk[1])


@functools.partial(jax.jit, static_argnames=("cfg", "engine", "interpret"))
def _sort_chunk(keys, leaves, cfg, engine, interpret):
    """Sort one staged chunk; emit the run as order-preserving unsigned bits."""
    if leaves:
        sk, sv = hybrid_sort(keys, leaves, cfg=cfg, engine=engine,
                             interpret=interpret)
    else:
        sk = hybrid_sort(keys, cfg=cfg, engine=engine, interpret=interpret)
        sv = ()
    return bijection.to_ordered_bits(sk), sv


@functools.partial(jax.jit, static_argnames=("lens", "kway", "tile", "n",
                                             "interpret"),
                   donate_argnums=(2, 3))
def merge_round(src_keys, src_vals, alt_keys, alt_vals, *, lens, kway: int,
                tile: int, n: int, interpret: bool = True):
    """One k-way merge round: diagonal partition + ONE merge-kernel launch.

    ``lens`` is the static tuple of current run lengths; groups of up to
    ``kway`` adjacent runs merge into one run each.  The partition tables are
    sort-free binary searches; the data movement is the single
    ``kway_merge_round`` launch (the per-round census gate).  The alternate
    buffers are donated.
    """
    tables = kmerge.merge_path_partition(src_keys, lens, kway, tile)
    return kmerge.kway_merge_round(src_keys, src_vals, alt_keys, alt_vals,
                                   *tables, kway=kway, tpb=tile, n=n,
                                   interpret=interpret)


class _Job(NamedTuple):
    """One slab strip of one merge group, with its host source/target runs."""
    strip: kmerge.SpillStrip
    kruns: list           # host key runs of the group (np, unsigned bits)
    vruns: list           # host value runs: per run a tuple of leaves
    mk: np.ndarray        # merged host key run being assembled
    mv: Tuple[np.ndarray, ...]


def _spill_merge(keys_h, vals_h, *, kway: int, tile: int, slab: int,
                 interpret: bool, ledger: _DeviceLedger):
    """Host-spilled merge rounds: stream every group through device slabs.

    ``keys_h``/``vals_h`` are the host-resident sorted runs (unsigned bits).
    Each round plans slab-sized strips for every multi-run group
    (single-run leftovers carry over host-side for free), then streams the
    strip list with the chunk phase's double-buffering discipline extended
    with D2H: strip i+1's upload and strip i−1's download are in flight
    while strip i's ``kway_merge_round`` launch runs.  Returns the final
    ``(keys, values, rounds, up_bytes, down_bytes)``; the device footprint
    never exceeds a handful of slabs (see ``_SLAB_FOOTPRINT``), which is
    what makes the §5 beyond-device-memory claim literal.
    """
    udtype = keys_h[0].dtype
    sentinel = udtype.type(~np.zeros((), udtype))
    bufsize = pad_length(slab, tile)
    up_total = down_total = 0
    rounds = 0

    def stage(job):
        nonlocal up_total
        strip, kruns, vruns = job.strip, job.kruns, job.vruns
        K = len(kruns)
        wins = [slice(strip.win_lo[r], strip.win_lo[r] + strip.win_len[r])
                for r in range(K)]
        up_k = np.concatenate([kruns[r][wins[r]] for r in range(K)])
        up_v = tuple(np.concatenate([vruns[r][li][wins[r]]
                                     for r in range(K)])
                     for li in range(len(vruns[0])))
        dev_k = jax.device_put(up_k)
        dev_v = tuple(jax.device_put(v) for v in up_v)
        up_bytes = up_k.nbytes + sum(v.nbytes for v in up_v)
        ledger.alloc(up_bytes)
        up_total += up_bytes
        tabs = tuple(jnp.asarray(t) for t in strip.tables)
        tab_bytes = sum(t.nbytes for t in strip.tables)
        ledger.alloc(tab_bytes)
        # pad the exact upload out to the fixed slab (sentinel keys, zero
        # values) so every strip of a round shares one kernel signature
        pad = bufsize - strip.out_len
        slab_k = jnp.concatenate([dev_k, jnp.full((pad,), sentinel, udtype)])
        slab_v = tuple(jnp.concatenate([v, jnp.zeros((pad,), v.dtype)])
                       for v in dev_v)
        slab_bytes = slab_k.nbytes + sum(v.nbytes for v in slab_v)
        ledger.alloc(slab_bytes)
        ledger.free(up_bytes)
        return slab_k, slab_v, tabs, slab_bytes + tab_bytes

    def launch(staged):
        slab_k, slab_v, tabs, held = staged
        alt_k = jnp.full((bufsize,), sentinel, udtype)
        alt_v = tuple(jnp.zeros((bufsize,), v.dtype) for v in slab_v)
        alt_bytes = alt_k.nbytes + sum(v.nbytes for v in alt_v)
        ledger.alloc(alt_bytes)
        out_k, out_v = kmerge.kway_merge_round(
            slab_k, slab_v, alt_k, alt_v, *tabs, kway=kway, tpb=tile,
            n=slab, interpret=interpret)
        return out_k, out_v, held + alt_bytes

    def collect(launched, job):
        nonlocal down_total
        out_k, out_v, held = launched
        lo, sl = job.strip.out_lo, job.strip.out_len
        kb = np.asarray(out_k[:sl])
        job.mk[lo:lo + sl] = kb
        down = kb.nbytes
        for li, v in enumerate(out_v):
            vb = np.asarray(v[:sl])
            job.mv[li][lo:lo + sl] = vb
            down += vb.nbytes
        down_total += down
        ledger.free(held)

    while len(keys_h) > 1:
        next_k, next_v, jobs = [], [], []
        for grp in kmerge.merge_groups(list(range(len(keys_h))), kway):
            if len(grp) == 1:           # leftover run: carried for free
                next_k.append(keys_h[grp[0]])
                next_v.append(vals_h[grp[0]])
                continue
            kruns = [keys_h[j] for j in grp]
            vruns = [vals_h[j] for j in grp]
            glen = sum(r.shape[0] for r in kruns)
            mk = np.empty(glen, udtype)
            mv = tuple(np.empty(glen, v.dtype) for v in vruns[0])
            next_k.append(mk)
            next_v.append(mv)
            for strip in kmerge.spill_group_plan(kruns, kway, tile, slab):
                jobs.append(_Job(strip, kruns, vruns, mk, mv))
        staged = stage(jobs[0])
        prev = None
        for i, job in enumerate(jobs):
            nxt = stage(jobs[i + 1]) if i + 1 < len(jobs) else None  # up i+1
            launched = launch(staged)                                # run i
            if prev is not None:
                collect(*prev)                                       # down i-1
            prev = (launched, job)
            staged = nxt
        collect(*prev)
        keys_h, vals_h = next_k, next_v
        rounds += 1
    return keys_h[0], vals_h[0], rounds, up_total, down_total


def oocsort(reader, chunk_elems: int, values: Any = None,
            cfg: Optional[model.SortConfig] = None,
            engine: Optional[str] = None, interpret: Optional[bool] = None,
            kway: int = 4, tile: int = 256, return_stats: bool = False,
            spill_budget_bytes: Optional[int] = None,
            device_slab_elems: Optional[int] = None):
    """Sort a host-resident array (or chunk stream) larger than one device run.

    ``reader`` is a 1-D numpy array, an iterable of 1-D key chunks (all of
    one dtype), or an iterable of ``(keys, values)`` chunk tuples;
    ``values`` (array-input only) is a 1-D array or pytree of 1-D arrays
    permuted alongside the keys (flat per-key slabs — the merge kernel's
    payload layout).  The
    input is cut into runs of ``chunk_elems`` keys; each run is sorted
    on-device by ``hybrid_sort`` (``cfg``/``engine`` as there) while the next
    chunk's ``jax.device_put`` is in flight, and the runs are merged by
    ⌈log_``kway``⌉ rounds of the merge-path kernel, one Pallas launch per
    round on donated ping-pong buffers.

    Setting ``spill_budget_bytes`` (a hard device-byte budget) and/or
    ``device_slab_elems`` (an explicit slab capacity, floored to a multiple
    of ``tile``) switches the merge phase to the **host-spill streaming**
    regime: runs live host-side between rounds and every round streams
    through a bounded handful of slab-sized device buffers (double-buffered
    uploads/downloads, one kernel launch per slab sweep; worst case modeled
    by ``_spill_peak_bytes``), so device memory stays bounded by the budget
    no matter how large the input.  When a budget is given,
    ``chunk_elems`` is clamped so the chunk phase fits it too, and the
    returned ``OocStats.device_high_water_bytes`` stays under it.

    Returns host numpy arrays: ``sorted_keys``, or ``(sorted_keys,
    permuted_values)`` when values were given; append an :class:`OocStats`
    when ``return_stats``.  Pair movement is consistent but — like
    ``hybrid_sort`` — not stable across equal keys *within* a chunk; across
    chunks the merge keeps run order (ties break by run index).
    """
    if chunk_elems < 1:
        raise ValueError("chunk_elems must be >= 1")
    if kway < 2:
        raise ValueError("kway must be >= 2")
    if tile < 8:
        raise ValueError("tile must be >= 8")
    spill = spill_budget_bytes is not None or device_slab_elems is not None
    if spill_budget_bytes is not None and spill_budget_bytes < 1:
        raise ValueError("spill_budget_bytes must be >= 1")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    chunks, treedef, key_dtype, empty_leaves = _rechunk(
        _as_stream(reader, values), chunk_elems)
    had_values = treedef is not None and treedef.num_leaves > 0

    def finish(keys_np, leaves_np, stats):
        out = (keys_np,) if not had_values else \
            (keys_np, jax.tree.unflatten(treedef, list(leaves_np)))
        if return_stats:
            out = out + (stats,)
        return out[0] if len(out) == 1 else out

    if key_dtype is None:
        raise ValueError("empty iterator reader: yield at least one "
                         "(possibly empty) chunk to fix the dtype")

    # --- spill plan: slab capacity + chunk clamp from the device budget ----
    # (validated before the empty-input return so a misconfigured slab or
    # budget fails input-independently, not just on the first non-empty run)
    elem_bytes = np.dtype(key_dtype).itemsize + \
        sum(v.dtype.itemsize for v in empty_leaves)
    slab = 0
    if spill:
        slab = device_slab_elems
        if slab is not None:
            slab -= slab % tile
            if slab < tile:
                raise ValueError("device_slab_elems must be >= tile")
        if spill_budget_bytes is not None:
            # the real constraint is the modeled worst case (incl. pad tile
            # + descriptor tables, which matter at tight budgets): start
            # from the explicit slab, or the footprint reservation when
            # deriving, and shrink tile by tile until the peak provably fits
            if slab is None:
                slab = spill_budget_bytes // (_SLAB_FOOTPRINT * elem_bytes)
                slab -= slab % tile
            while slab >= tile and _spill_peak_bytes(
                    slab, tile, elem_bytes, kway) > spill_budget_bytes:
                slab -= tile
            if slab < tile:
                raise ValueError(
                    f"spill_budget_bytes={spill_budget_bytes} too small: "
                    f"need >= "
                    f"{_spill_peak_bytes(tile, tile, elem_bytes, kway)} "
                    f"for tile={tile} (worst-case stream of one-tile slabs)")
            # largest chunk whose engine-aware peak (kernel chunks allocate
            # pad_length(n, kpb)-sized ping-pong pairs) fits the budget
            peak = lambda c: _chunk_peak_bytes(c, elem_bytes, cfg, engine,
                                               key_dtype)
            if peak(1) > spill_budget_bytes:
                raise ValueError(
                    f"spill_budget_bytes={spill_budget_bytes} too small for "
                    f"the chunk phase: even a 1-element chunk sort models "
                    f"{peak(1)} device bytes (engine "
                    f"{resolve_engine(engine)!r}; the kernel engine pads to "
                    f"whole cfg.kpb tiles — pass a smaller-kpb cfg)")
            lo = 1
            hi = max(1, spill_budget_bytes // (_CHUNK_FOOTPRINT * elem_bytes))
            while peak(hi) <= spill_budget_bytes and hi < chunk_elems:
                hi = min(2 * hi, chunk_elems)    # the reservation start is
                # conservative for the jnp engines; grow to the model's edge
            while lo < hi:
                mid = (lo + hi + 1) // 2
                lo, hi = (mid, hi) if peak(mid) <= spill_budget_bytes \
                    else (lo, mid - 1)
            if lo < chunk_elems:
                chunk_elems = lo
                chunks = _split_chunks(chunks, chunk_elems)

    if not chunks:
        stats = OocStats(0, 0, chunk_elems, 0, 0,
                         spill_slab_elems=slab if spill else 0)
        return finish(np.empty((0,), key_dtype), empty_leaves, stats)

    k = bijection.key_bits(key_dtype)
    if k > 32 and not jax.config.jax_enable_x64:
        raise RuntimeError("64-bit keys require jax_enable_x64")
    if not jax.config.jax_enable_x64:
        # leaf dtypes are chunk-uniform (_rechunk), so one chunk decides;
        # device_put would otherwise silently truncate 64-bit payloads
        for v in chunks[0][1]:
            if v.dtype.itemsize > 4:
                raise RuntimeError(
                    f"64-bit value leaves ({v.dtype}) require "
                    "jax_enable_x64")

    # --- chunk phase: double-buffered staging, §5's upload/sort overlap ----
    ledger = _DeviceLedger()
    num_chunks = len(chunks)
    lens = [c[0].shape[0] for c in chunks]
    n = sum(lens)
    h2d = d2h = 0

    staged = jax.device_put(chunks[0])
    staged_bytes = _chunk_nbytes(chunks[0])
    ledger.alloc(staged_bytes)
    h2d += staged_bytes
    runs = []          # device runs (resident merge) or host runs (spill)
    pending = None     # spill: (device run, run bytes, working bytes) to D2H
    for i in range(num_chunks):
        nxt = nxt_bytes = None
        if i + 1 < num_chunks:
            nxt_bytes = _chunk_nbytes(chunks[i + 1])
            nxt = jax.device_put(chunks[i + 1])      # stage i+1 ...
            ledger.alloc(nxt_bytes)
            h2d += nxt_bytes
        ws = _chunk_working_bytes(chunks[i][0].shape[0], elem_bytes, cfg,
                                  engine, key_dtype)
        ledger.alloc(ws)                             # sort ping-pong model
        run = _sort_chunk(*staged, cfg, engine, interpret)     # ... sort i
        ledger.alloc(staged_bytes)                   # the sorted run
        if spill:
            if pending is not None:                  # ... download run i-1
                runs.append((np.asarray(pending[0][0]),
                             tuple(np.asarray(v) for v in pending[0][1])))
                d2h += pending[1]
                ledger.free(pending[2])
            pending = (run, staged_bytes, 2 * staged_bytes + ws)
        else:
            runs.append(run)
            ledger.free(staged_bytes + ws)           # staged + working set
        staged, staged_bytes = nxt, nxt_bytes
    if spill:
        runs.append((np.asarray(pending[0][0]),
                     tuple(np.asarray(v) for v in pending[0][1])))
        d2h += pending[1]
        ledger.free(pending[2])
    chunk_up, chunk_down = h2d, d2h

    # --- merge phase ------------------------------------------------------
    rounds = 0
    spill_up = spill_down = 0
    if spill:
        keys_h, vals_h, rounds, spill_up, spill_down = (
            (runs[0][0], runs[0][1], 0, 0, 0) if num_chunks == 1 else
            _spill_merge([r[0] for r in runs], [r[1] for r in runs],
                         kway=kway, tile=tile, slab=slab,
                         interpret=interpret, ledger=ledger))
        h2d += spill_up
        d2h += spill_down
        keys_np = bijection.from_ordered_bits_np(keys_h, key_dtype)
        leaves_np = tuple(vals_h)
    elif num_chunks == 1:
        ck, cv = runs[0]             # single run: no marshalling, no merge
    else:
        # the padded current/alternate buffers follow fused.make_ping_pong's
        # contract (sentinel key pad, zero value pad), built inline so run
        # marshalling is a single concatenate — one fewer sweep than padding
        # a pre-concatenated copy
        udtype = runs[0][0].dtype
        n_pad = pad_length(n, tile)
        sentinel = ~jnp.zeros((), udtype)
        ck = jnp.concatenate([r[0] for r in runs] +
                             [jnp.full((n_pad - n,), sentinel, udtype)])
        num_leaves = len(runs[0][1])
        cv = tuple(
            jnp.concatenate([r[1][i] for r in runs] +
                            [jnp.zeros((n_pad - n,), runs[0][1][i].dtype)])
            for i in range(num_leaves))
        ak = jnp.full_like(ck, sentinel)
        av = tuple(jnp.zeros_like(v) for v in cv)
        ledger.alloc(2 * n_pad * elem_bytes)         # flat ping-pong pair
        ledger.free(n * elem_bytes)                  # per-run buffers release
        del runs, staged, chunks     # the merge phase's footprint is the two
        # flat ping-pong buffers only — the very footprint the spill regime
        # replaces with bounded slabs

        while len(lens) > 1:
            nk, nv = merge_round(ck, cv, ak, av, lens=tuple(lens), kway=kway,
                                 tile=tile, n=n, interpret=interpret)
            ak, av = ck, cv                  # old current donates next round
            ck, cv = nk, nv
            lens = [sum(g) for g in kmerge.merge_groups(lens, kway)]
            rounds += 1

    if not spill:
        keys_np = np.asarray(bijection.from_ordered_bits(ck[:n], key_dtype))
        leaves_np = tuple(np.asarray(v[:n]) for v in cv)
        d2h += keys_np.nbytes + sum(v.nbytes for v in leaves_np)
    stats = OocStats(
        num_chunks, rounds, chunk_elems, h2d, d2h,
        device_high_water_bytes=ledger.high,
        chunk_link_bytes=chunk_up + (chunk_down if spill else d2h),
        spill_link_bytes=spill_up + spill_down,
        rounds_spilled=rounds if spill else 0,
        spill_slab_elems=slab)
    return finish(keys_np, leaves_np, stats)
