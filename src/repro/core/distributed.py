"""Distributed sorting across a device mesh (paper §5, re-derived for TPU).

The paper's heterogeneous sort pipelines PCIe H2D / on-GPU sort / D2H over s
chunks and merges the sorted runs on the CPU.  On a TPU pod the slow link is
ICI (chip-to-chip) instead of PCIe and the merge lives next to the exchange.
The structure is isomorphic:

  paper (Figs. 4/5)                     this module
  ---------------------------------     ------------------------------------
  split input into s chunks             split each shard's data into s chunks
  H2D transfer of chunk i+1             all_to_all exchange of chunk i+1
    overlapped with on-GPU sort of        overlapped with local hybrid sort /
    chunk i (full-duplex PCIe)            merge of chunk i (bidirectional ICI;
                                          XLA latency-hiding scheduler)
  in-place replacement of returned      XLA buffer donation/reuse of the
    chunk memory (Fig. 5)                 exchanged chunk buffers
  CPU parallel multiway merge           per-shard multiway merge of received
                                          sorted runs (merge-path via
                                          vectorised binary search)

Shard splitters are *sample-based* (deterministic sample sort — Dehne &
Zaboli, cited §1) with duplicate-only rank interleaving, so only exactly-equal
keys are split across shards and global order is preserved for any
distribution — the zero-entropy case degrades to zero exchange traffic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import bijection, model
from repro.core.hybrid import hybrid_sort
from repro.core.segmented import counting_partition, multiway_merge


def _select_splitters(gsample_sorted: jnp.ndarray, nshards: int) -> jnp.ndarray:
    """(nshards - 1,) splitters from a sorted global sample.

    Guards the degenerate case where the gathered sample is smaller than the
    shard count (e.g. ``num_chunks > n_local`` leaves empty chunks): the
    regular stride would be 0 and ``gsample[0::0]`` is invalid.  With too few
    samples every shard boundary collapses onto one splitter level; the
    duplicate-rank interleaving of ``_dest_shards`` then spreads ties across
    all shards, so correctness (global order) is preserved — only balance
    degrades, which is the best any sample sort can do sample-starved.
    """
    total = gsample_sorted.shape[0]
    step = total // nshards
    if step == 0:
        fill = (gsample_sorted[0] if total
                else jnp.zeros((), gsample_sorted.dtype))
        return jnp.full((nshards - 1,), fill, gsample_sorted.dtype)
    sel = gsample_sorted[step::step][: nshards - 1]
    pad = (nshards - 1) - sel.shape[0]
    if pad > 0:  # unreachable for step >= 1; kept as a static safety net
        sel = jnp.concatenate(
            [sel, jnp.full((pad,), gsample_sorted[-1], gsample_sorted.dtype)])
    return sel


def _make_splitters(local_sample, axis_name: str, nshards: int):
    """Global shard splitters from a regular sample of the sorted local data
    (deterministic sample sort).  ``nshards`` is the static mesh axis size."""
    gsample = jax.lax.all_gather(local_sample, axis_name).reshape(-1)
    return _select_splitters(jnp.sort(gsample), nshards)


def _dest_shards(sorted_ukeys, splitters, axis_name: str, nshards: int):
    """Destination shard per (locally sorted) key.

    Ties with splitter values are cycled across their allowed shard range —
    safe, because only equal keys ever cross a splitter boundary, and it keeps
    the per-(source, dest) load <= chunk/spread so the static all_to_all
    capacity holds even for the constant (zero-entropy) distribution.
    """
    my = jax.lax.axis_index(axis_name)
    n_local = sorted_ukeys.shape[0]
    lo = jnp.searchsorted(splitters, sorted_ukeys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(splitters, sorted_ukeys, side="right").astype(jnp.int32)
    spread = hi - lo + 1
    first = jnp.searchsorted(sorted_ukeys, sorted_ukeys, side="left")
    tie_rank = jnp.arange(n_local, dtype=jnp.int32) - first.astype(jnp.int32)
    return lo + (tie_rank + my) % spread


def _exchange(sorted_ukeys, dest_shard, nshards: int, capacity: int, sentinel,
              axis_name: str, engine=None):
    """Partition by destination shard (one counting pass, §4.1), pad to the
    static all_to_all capacity, exchange keys and validity counts.

    The shard partition routes through the same engine-selected
    ``counting_partition`` as MoE dispatch and length bucketing (core.plan).
    """
    part = counting_partition(dest_shard, nshards, engine=engine)
    position = part.dest - part.offsets[dest_shard]
    kept = position < capacity
    slot = jnp.where(kept, dest_shard * capacity + position, nshards * capacity)
    buf = jnp.full((nshards * capacity + 1,), sentinel, sorted_ukeys.dtype)
    buf = buf.at[slot].set(sorted_ukeys, mode="drop")
    send = buf[:-1].reshape(nshards, capacity)
    sent_counts = jnp.minimum(part.counts, capacity)
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
    recv_counts = jax.lax.all_to_all(sent_counts.reshape(nshards, 1), axis_name,
                                     split_axis=0, concat_axis=0)
    overflow = (part.counts > capacity).any()
    return recv, recv_counts.sum(), overflow


def make_distributed_sort(mesh, axis_name: str = "data", *,
                          oversample: int = 64, slack: float = 2.0,
                          num_chunks: int = 1,
                          cfg: Optional[model.SortConfig] = None,
                          spec: Optional[P] = None,
                          engine: Optional[str] = None):
    """Build a shard_map'd distributed sort over one mesh axis.

    Returns fn: (n_local,) keys per shard -> (padded sorted keys per shard,
    (1,) valid count per shard, (1,) overflow flag per shard).  The first
    ``valid`` entries of consecutive shards concatenate to the global sorted
    sequence.  ``num_chunks > 1`` enables the §5 pipelined schedule.
    """
    spec = spec if spec is not None else P(axis_name)

    def dsort(keys):
        ukeys = bijection.to_ordered_bits(keys)
        sentinel = ~jnp.zeros((), ukeys.dtype)   # all-ones == top of key order
        n_local = ukeys.shape[0]
        chunk = n_local // num_chunks
        nshards = mesh.shape[axis_name]
        capacity = max(1, int(slack * chunk / nshards))

        # stage 1 (paper: on-GPU sort of each chunk): local hybrid sorts
        pieces = [hybrid_sort(ukeys[c * chunk:(c + 1) * chunk], cfg=cfg,
                              engine=engine)
                  for c in range(num_chunks)]
        # one consistent splitter set across all chunks
        m = max(1, min(nshards * oversample // num_chunks, chunk))
        stride = max(chunk // m, 1)
        sample = jnp.concatenate([p[::stride][:m] for p in pieces])
        splitters = _make_splitters(sample, axis_name, nshards)

        # stage 2/3 (paper: pipelined transfer + merge): exchange chunk c+1
        # overlaps the merge of chunk c — no data dependency between them
        runs, counts, over = [], [], []
        for piece in pieces:
            dest = _dest_shards(piece, splitters, axis_name, nshards)
            recv, cnt, ov = _exchange(piece, dest, nshards, capacity,
                                      sentinel, axis_name, engine=engine)
            # each received row is a sorted run (stable partition of sorted
            # input) -> multiway merge, not a re-sort
            runs.append(multiway_merge(recv))
            counts.append(cnt)
            over.append(ov)
        merged = runs[0] if num_chunks == 1 else multiway_merge(jnp.stack(runs))
        valid = functools.reduce(jnp.add, counts)
        overflow = functools.reduce(jnp.logical_or, over)
        out = bijection.from_ordered_bits(merged, keys.dtype)
        return out, valid.reshape(1), overflow.reshape(1)

    return _shard_map(dsort, mesh, (spec,), (spec, spec, spec))


def _shard_map(fn, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (older ones: experimental module)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)
