"""Distributed sorting across a device mesh (paper §5, re-derived for TPU).

The paper's heterogeneous sort pipelines PCIe H2D / on-GPU sort / D2H over s
chunks and merges the sorted runs on the CPU.  On a TPU pod the slow link is
ICI (chip-to-chip) instead of PCIe and the merge lives next to the exchange.
The structure is isomorphic:

  paper (Figs. 4/5)                     this module
  ---------------------------------     ------------------------------------
  split input into s chunks             split each shard's data into s chunks
  H2D transfer of chunk i+1             all_to_all exchange of chunk i+1
    overlapped with on-GPU sort of        overlapped with local hybrid sort /
    chunk i (full-duplex PCIe)            merge of chunk i (bidirectional ICI;
                                          XLA latency-hiding scheduler)
  in-place replacement of returned      XLA buffer donation/reuse of the
    chunk memory (Fig. 5)                 exchanged chunk buffers
  CPU parallel multiway merge           per-shard multiway merge of received
                                          sorted runs (merge-path via
                                          vectorised binary search)

Shard splitters are *sample-based* (GPU Sample Sort shape: oversampled
splitter selection — Dehne & Zaboli, cited §1) with duplicate-only rank
interleaving, so only exactly-equal keys are split across shards and global
order is preserved for any distribution — the zero-entropy case degrades to
zero exchange traffic.

Capacity overflow (a splitter set that routes more than the static
all_to_all capacity to one (source, dest) pair) is handled by bounded
*splitter refinement*: the exchange re-samples at ``refine``x the previous
sample density and replays bucketing + all_to_all, up to ``max_attempts``
times.  Attempts are ledgered in ``DistStats.exchange_attempts``; only if
every attempt overflows does ``DistStats.overflow`` stay set (the residual
flag, not a silent one).  The retry sites are ``lax.cond``-guarded, so the
launch census keeps ONE ``pallas_call`` per *executed* counting pass — the
same executed-vs-nominal idiom as the adaptive pass elision (§4.2).

The whole pipeline is comparison-sort-free under ``engine="kernel"``: local
chunk sorts are ``hybrid_sort``, sample/splitter selection merges sorted
pieces (merge-path binary search), per-shard bucketing routes through
``plan.single_pass_partition`` (one fused counting pass), and the finish is
a single high-fan-in ``multiway_merge`` over all received runs plus one
2-bucket validity-compaction pass.
"""
from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import bijection, model
from repro.core.hybrid import hybrid_sort
from repro.core.segmented import counting_partition, multiway_merge


class DistStats(NamedTuple):
    """Per-shard exchange ledger (global shapes ``(nshards,)`` after
    shard_map; replicated entries repeat the same value on every shard).

    exchange_attempts  executed splitter-refinement attempts (replicated;
                       1 = first splitter set fit, k > 1 = k-1 retries)
    overflow           residual overflow after the last attempt (replicated;
                       True means ``valid`` undercounts — capacity clipped)
    valid              number of real keys in this shard's output prefix
    peak_recv          max keys received over (chunk, source) rows — the
                       balance measure the ≤ 2x skew gate reads
    """
    exchange_attempts: jnp.ndarray
    overflow: jnp.ndarray
    valid: jnp.ndarray
    peak_recv: jnp.ndarray


def _select_splitters(gsample_sorted: jnp.ndarray, nshards: int,
                      oversample: int = 8) -> jnp.ndarray:
    """(nshards - 1,) splitters from a sorted global sample.

    Takes an ``oversample * nshards`` evenly-ranked oversample of the sorted
    sample first and selects every ``oversample``-th entry — equivalent to
    even quantiles ``gsample[(i * total) // nshards]``.  The previous
    ``step::step`` regular stride truncated ``total % nshards`` trailing
    samples, which on clustered data parks every key above the last retained
    rank on the final shard (> 2x imbalance whenever the sample total is not
    ≈ a multiple of nshards).

    The degenerate case (gathered sample smaller than the shard count, e.g.
    ``num_chunks > n_local`` leaves empty chunks) needs no special stride
    guard any more: even-rank selection just repeats sample values, shard
    boundaries collapse onto few splitter levels, and the duplicate-rank
    interleaving of ``_dest_shards`` spreads the ties — correctness (global
    order) is preserved, only balance degrades, which is the best any sample
    sort can do sample-starved.
    """
    total = gsample_sorted.shape[0]
    if total == 0 or nshards == 1:
        return jnp.zeros((nshards - 1,), gsample_sorted.dtype)
    s = max(1, int(oversample))
    ranks = (jnp.arange(s * nshards) * total) // (s * nshards)
    over = gsample_sorted[ranks]
    return over[s::s]


def _even_sample_ranks(n: int, m: int) -> jnp.ndarray:
    """m evenly-spaced ranks into a length-n sorted array (sorted output)."""
    return (jnp.arange(m) * n) // m


def _make_splitters(local_sample, axis_name: str, nshards: int,
                    sel_oversample: int = 8):
    """Global shard splitters from per-shard sorted samples.

    Sort-free: ``all_gather`` keeps the per-shard rows intact (each already
    sorted) and the global order comes from a ``multiway_merge`` — no HLO
    sort op, so the kernel engine's sort-free gate extends over the
    exchange.  ``nshards`` is the static mesh axis size.
    """
    g = jax.lax.all_gather(local_sample, axis_name)     # (nshards, m) sorted
    gsorted = g.reshape(-1) if nshards == 1 else multiway_merge(g)
    return _select_splitters(gsorted, nshards, oversample=sel_oversample)


def _dest_shards(sorted_ukeys, splitters, nshards: int, my):
    """Destination shard per (locally sorted) key.

    Ties with splitter values are cycled across their allowed shard range —
    safe, because only equal keys ever cross a splitter boundary, and it
    keeps the per-(source, dest) load <= chunk/spread so the static
    all_to_all capacity holds even for the constant (zero-entropy)
    distribution.  ``my`` is this shard's index (pass
    ``jax.lax.axis_index(axis)`` inside shard_map; any int in host tests),
    offsetting the cycle so different sources hit different shards first.
    """
    n_local = sorted_ukeys.shape[0]
    lo = jnp.searchsorted(splitters, sorted_ukeys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(splitters, sorted_ukeys, side="right").astype(jnp.int32)
    spread = hi - lo + 1
    first = jnp.searchsorted(sorted_ukeys, sorted_ukeys, side="left")
    tie_rank = jnp.arange(n_local, dtype=jnp.int32) - first.astype(jnp.int32)
    return lo + (tie_rank + my) % spread


def _exchange(sorted_ukeys, leaves, dest_shard, nshards: int, capacity: int,
              sentinel, axis_name: str, engine=None, interpret=None):
    """Partition by destination shard (one counting pass, §4.1), pad to the
    static all_to_all capacity, exchange keys, payload leaves and validity
    counts.

    The shard partition routes through the same engine-selected
    ``counting_partition`` as MoE dispatch and length bucketing (core.plan),
    so the one-launch-per-counting-pass census extends to the exchange.
    """
    part = counting_partition(dest_shard, nshards, engine=engine,
                              interpret=interpret)
    position = part.dest - part.offsets[dest_shard]
    kept = position < capacity
    slot = jnp.where(kept, dest_shard * capacity + position, nshards * capacity)
    buf = jnp.full((nshards * capacity + 1,), sentinel, sorted_ukeys.dtype)
    buf = buf.at[slot].set(sorted_ukeys, mode="drop")
    recv = jax.lax.all_to_all(buf[:-1].reshape(nshards, capacity), axis_name,
                              split_axis=0, concat_axis=0)
    recv_leaves = []
    for leaf in leaves:
        lbuf = jnp.zeros((nshards * capacity + 1,), leaf.dtype)
        lbuf = lbuf.at[slot].set(leaf, mode="drop")
        recv_leaves.append(
            jax.lax.all_to_all(lbuf[:-1].reshape(nshards, capacity),
                               axis_name, split_axis=0, concat_axis=0))
    sent_counts = jnp.minimum(part.counts, capacity)
    recv_counts = jax.lax.all_to_all(sent_counts.reshape(nshards, 1),
                                     axis_name, split_axis=0,
                                     concat_axis=0).reshape(nshards)
    overflow = (part.counts > capacity).any()
    return recv, tuple(recv_leaves), recv_counts, overflow


def make_distributed_sort(mesh, axis_name: str = "data", *,
                          oversample: int = 64, slack: float = 2.0,
                          num_chunks: int = 1, max_attempts: int = 3,
                          refine: int = 4,
                          cfg: Optional[model.SortConfig] = None,
                          spec: Optional[P] = None,
                          engine: Optional[str] = None,
                          interpret: Optional[bool] = None):
    """Build a shard_map'd distributed sort over one mesh axis.

    Returns ``fn(keys[, values]) -> (out_keys[, out_values], DistStats)``:
    per shard ``(n_local,)`` keys (plus an optional pytree of same-length
    payload leaves) map to capacity-padded sorted outputs whose first
    ``stats.valid[i]`` entries per shard concatenate to the global sorted
    sequence (``valid_concat``).  ``num_chunks > 1`` enables the §5
    pipelined schedule; ``oversample`` is the per-shard splitter sample
    size; overflow triggers up to ``max_attempts - 1`` splitter-refinement
    replays at ``refine``x sample density (see module docstring).
    """
    spec = spec if spec is not None else P(axis_name)
    nshards = mesh.shape[axis_name]
    if max_attempts < 1:
        raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")

    def dsort(keys, leaves):
        ukeys = bijection.to_ordered_bits(keys)
        sentinel = ~jnp.zeros((), ukeys.dtype)   # all-ones == top of key order
        n_local = ukeys.shape[0]
        chunk = n_local // num_chunks
        # slack prices the skew splitter error leaves behind; the additive
        # term prices what no splitter can fix — the per-(source, dest)
        # binomial cell variance of a small chunk (~4 standard deviations of
        # headroom, so tiny shards don't overflow on noise retries cannot
        # remove).  A source never sends more than its whole chunk to one
        # destination, so `chunk` caps the cell outright.
        base = slack * chunk / nshards
        capacity = max(1, min(chunk,
                              int(base + 4.0 * math.sqrt(max(base, 1.0)))))
        out_len = num_chunks * nshards * capacity

        if chunk == 0:
            # degenerate: num_chunks > n_local — nothing to exchange, keep
            # the shape contract traceable (valid = 0, zero attempts)
            zero = jnp.zeros((1,), jnp.int32)
            return (bijection.from_ordered_bits(
                        jnp.full((out_len,), sentinel, ukeys.dtype),
                        keys.dtype),
                    tuple(jnp.zeros((out_len,), l.dtype) for l in leaves),
                    DistStats(zero, jnp.zeros((1,), bool), zero, zero))
        if n_local % num_chunks:
            raise ValueError(
                f"n_local={n_local} must divide into num_chunks={num_chunks}")

        my = jax.lax.axis_index(axis_name)

        # stage 1 (paper: on-GPU sort of each chunk): local hybrid sorts.
        # With payloads a single int32 rank rides the sort so the local-sort
        # launch count stays independent of the number of payload leaves.
        pieces = []
        for c in range(num_chunks):
            uchunk = ukeys[c * chunk:(c + 1) * chunk]
            if leaves:
                pk, pidx = hybrid_sort(uchunk,
                                       jnp.arange(chunk, dtype=jnp.int32),
                                       cfg=cfg, engine=engine,
                                       interpret=interpret)
            else:
                pk, pidx = hybrid_sort(uchunk, cfg=cfg, engine=engine,
                                       interpret=interpret), None
            pieces.append((pk, pidx))

        def attempt(a):
            """One splitter-selection + exchange round at refine^a density."""
            s_a = oversample * (refine ** a)
            m = max(1, min(-(-s_a // num_chunks), chunk))
            ranks = _even_sample_ranks(chunk, m)
            samples = jnp.stack([pk[ranks] for pk, _ in pieces])
            local_sample = (samples[0] if num_chunks == 1
                            else multiway_merge(samples))
            splitters = _make_splitters(local_sample, axis_name, nshards)
            rks, rls, rcs, ovs = [], [], [], []
            for c, (pk, pidx) in enumerate(pieces):
                dest = _dest_shards(pk, splitters, nshards, my)
                pleaves = (tuple(l[c * chunk:(c + 1) * chunk][pidx]
                                 for l in leaves) if leaves else ())
                rk, rl, rc, ov = _exchange(pk, pleaves, dest, nshards,
                                           capacity, sentinel, axis_name,
                                           engine=engine, interpret=interpret)
                rks.append(rk)
                rls.append(rl)
                rcs.append(rc)
                ovs.append(ov)
            rk = jnp.stack(rks)                      # (C, nshards, capacity)
            rl = (tuple(jnp.stack(ls) for ls in zip(*rls)) if leaves else ())
            rc = jnp.stack(rcs)                      # (C, nshards)
            local_over = functools.reduce(jnp.logical_or, ovs)
            # replicated predicate: every shard must take the same retry
            # branch (the retry replays collectives)
            over = jax.lax.psum(local_over.astype(jnp.int32), axis_name) > 0
            return rk, rl, rc, over

        carry = attempt(0)
        attempts = jnp.int32(1)
        for a in range(1, max_attempts):
            prev_over = carry[3]
            carry = jax.lax.cond(prev_over,
                                 lambda _, a=a: attempt(a),
                                 lambda c: c, carry)
            attempts = attempts + prev_over.astype(jnp.int32)
        rk, rl, rc, over = carry

        # stage 2/3 finish: ONE high-fan-in merge over all C * nshards
        # received runs (Multiway Mergesort shape — no merge cascade), with
        # a flat slot id riding along to recover payloads and validity,
        # then one 2-bucket counting pass compacts valid keys in front of
        # the capacity padding (stable, so global order is preserved).
        runs = rk.reshape(num_chunks * nshards, capacity)
        slot_ids = jnp.arange(runs.size, dtype=jnp.int32).reshape(runs.shape)
        if runs.shape[0] == 1:
            merged, midx = runs[0], slot_ids[0]
        else:
            merged, midx = multiway_merge(runs, slot_ids)
        ok = (jnp.arange(capacity, dtype=jnp.int32)[None, :]
              < rc.reshape(-1, 1)).reshape(-1)[midx]
        cpart = counting_partition((~ok).astype(jnp.int32), 2, engine=engine,
                                   interpret=interpret)
        out_u = merged[cpart.perm]
        out_leaves = tuple(l.reshape(-1)[midx][cpart.perm] for l in rl)
        stats = DistStats(
            exchange_attempts=attempts.reshape(1),
            overflow=over.reshape(1),
            valid=rc.sum().astype(jnp.int32).reshape(1),
            peak_recv=rc.max().astype(jnp.int32).reshape(1))
        return bijection.from_ordered_bits(out_u, keys.dtype), out_leaves, stats

    def fn(keys, values: Any = None):
        leaves, treedef = jax.tree.flatten(values)
        for leaf in leaves:
            if leaf.shape[0] != keys.shape[0]:
                raise ValueError(
                    f"payload leaf length {leaf.shape[0]} != keys length "
                    f"{keys.shape[0]}")
        stats_spec = DistStats(spec, spec, spec, spec)
        sharded = _shard_map(dsort, mesh,
                             (spec, (spec,) * len(leaves)),
                             (spec, (spec,) * len(leaves), stats_spec))
        out_keys, out_leaves, stats = sharded(keys, tuple(leaves))
        if values is None:
            return out_keys, stats
        return out_keys, jax.tree.unflatten(treedef, list(out_leaves)), stats

    return fn


def valid_concat(out, valid):
    """Host-side: concatenate the valid prefixes of every shard's padded
    output (keys or any payload leaf) into the global sorted sequence."""
    valid = np.asarray(valid).reshape(-1)
    per = np.asarray(out).reshape(valid.shape[0], -1)
    return np.concatenate([per[i][: valid[i]] for i in range(valid.shape[0])])


def _shard_map(fn, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (older ones: experimental module)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# --- contract declaration (verified by repro.analysis; see analysis/contracts)
# Shard-body census: per chunk one full hybrid sort, per cond-guarded attempt
# per chunk one bucketing counting pass (2 sites), plus the 2-bucket validity
# compaction.  Link bytes re-derive the ICI table of kernels/__init__ from
# the collective-primitive result shapes: per attempt per chunk one keys +
# ``leaves`` payload + one counts all_to_all at capacity padding, per attempt
# one splitter-sample all_gather (samp lists the gathered per-shard sample
# lengths) and one scalar overflow psum.
ANALYSIS_CONTRACT = {
    "entry": "repro.core.distributed.make_distributed_sort",
    "census": {
        "launch_total": "chunks * (2 + classes)"
                        " + 2 * attempts * chunks + 2",
        "while_body_launches": "[1] * chunks",
    },
    "sort_free": True,
    "link": {
        "collective_counts": {
            "all_to_all": "attempts * chunks * (2 + leaves)",
            "all_gather": "attempts",
            "psum": "attempts",
        },
        "link_bytes": "((P - 1) / P) * ("
                      "attempts * chunks * P * (cap * (kb + vb) + 4)"
                      " + kb * P * sum(samp) + attempts * 2 * 4)",
    },
}
