"""Stable-partition rank/permutation engines for counting-sort passes.

A counting-sort pass needs, for every key, its destination slot: keys are
grouped by bucket id (digit value, possibly composite with a segment id) with
ties broken by input position (stability *within a pass* — the paper drops
stability *across* passes, not within one partitioning step).

Two engines compute the same permutation:

  * ``argsort`` — one XLA stable sort of the composite id.  O(n log n)
    comparisons but a single fused, heavily-optimised op; the default on CPU
    (and a perfectly good TPU fallback).
  * ``scan``    — the paper-faithful O(n) two-level scheme: per-chunk
    histograms + in-chunk ranks (what the Pallas kernels implement per tile),
    with a carried running histogram across chunks.  A first-class fallback
    engine wherever the Pallas kernels are unavailable but O(n log n)
    comparison sorts are unwanted.

A third engine name, ``kernel``, selects the Pallas tile pipeline (histogram →
multisplit → run copies); it lives in ``repro.kernels.ops`` because it moves
keys directly rather than producing a standalone ``dest`` array.  The sort
drivers accept any of the three (or ``auto``) and route through
``resolve_engine`` below.

Both jnp engines return ``dest`` with: element i moves to slot ``dest[i]``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

#: Engines understood by the sort drivers (hybrid_sort / lsd_sort).
ENGINES = ("argsort", "scan", "kernel")


def resolve_engine(engine=None, backend=None) -> str:
    """Resolve ``None``/``"auto"`` to the per-backend default engine.

    TPUs default to the Pallas ``kernel`` engine (the paper's O(n) pipeline);
    everything else defaults to ``argsort`` — interpret-mode kernels are
    bit-exact but slow, so on CPU they are opt-in.
    """
    if engine in (None, "auto"):
        backend = backend or jax.default_backend()
        return "kernel" if backend == "tpu" else "argsort"
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")
    return engine


def invert_permutation(perm: jnp.ndarray) -> jnp.ndarray:
    """dest[i] such that sorted[dest[i]] = x[i], given perm = argsort order."""
    n = perm.shape[0]
    return jnp.zeros((n,), dtype=perm.dtype).at[perm].set(jnp.arange(n, dtype=perm.dtype))


def stable_partition_dest_argsort(bucket: jnp.ndarray) -> jnp.ndarray:
    """Destination slots of a stable partition by ``bucket`` (int array)."""
    perm = jnp.argsort(bucket, stable=True)
    return invert_permutation(perm)


@functools.partial(jax.jit, static_argnames=("num_buckets", "chunk"))
def stable_partition_dest_scan(bucket: jnp.ndarray, num_buckets: int,
                               chunk: int = 2048) -> jnp.ndarray:
    """O(n) counting-rank engine: chunked scan with a carried histogram.

    Mirrors the TPU kernel structure: per-chunk one-hot histogram (MXU-shaped),
    in-chunk exclusive cumulative count, global exclusive bucket offsets, and a
    cross-chunk carry — the jnp analogue of the paper's block histograms (M3)
    plus the scatter offsets.
    """
    n = bucket.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    pad = (-n) % chunk
    b = jnp.pad(bucket.astype(jnp.int32), (0, pad), constant_values=num_buckets)
    nb = num_buckets + 1  # one trash bucket for padding
    tiles = b.reshape(-1, chunk)

    def tile_hist(row):
        return jnp.zeros((nb,), jnp.int32).at[row].add(1)

    hists = jax.vmap(tile_hist)(tiles)                       # (T, nb)
    total = hists.sum(axis=0)
    # global exclusive offsets per bucket
    g_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                             jnp.cumsum(total)[:-1].astype(jnp.int32)])
    # exclusive-over-tiles carry per bucket
    carry = jnp.concatenate([jnp.zeros((1, nb), jnp.int32),
                             jnp.cumsum(hists, axis=0)[:-1].astype(jnp.int32)])

    def tile_ranks(row, carry_row):
        if nb <= 4096:
            onehot = jax.nn.one_hot(row, nb, dtype=jnp.int32)  # (chunk, nb)
            incl = jnp.cumsum(onehot, axis=0)
            excl = incl - onehot                               # rank within tile
            in_tile = jnp.take_along_axis(excl, row[:, None], axis=1)[:, 0]
        else:
            # wide bucket spaces (the hybrid's composite segment x digit ids)
            # would make the one-hot (chunk, nb) matrix explode; count equal
            # predecessors pairwise instead — O(chunk) per element, still O(n)
            # overall with the chunk size fixed.
            i = jnp.arange(row.shape[0])
            eq_before = (row[None, :] == row[:, None]) & (i[None, :] < i[:, None])
            in_tile = eq_before.sum(axis=1).astype(jnp.int32)
        return g_off[row] + carry_row[row] + in_tile

    dest = jax.vmap(tile_ranks)(tiles, carry).reshape(-1)
    return dest[:n]


def stable_partition_dest(bucket: jnp.ndarray, num_buckets: int,
                          engine: str = "argsort") -> jnp.ndarray:
    if engine == "argsort":
        return stable_partition_dest_argsort(bucket)
    if engine == "scan":
        # wide bucket spaces take the pairwise in-chunk rank path, whose
        # time/memory is O(n * chunk) — shrink the chunk to keep it O(n)-ish
        chunk = 2048 if num_buckets <= 4096 else 256
        return stable_partition_dest_scan(bucket, num_buckets, chunk=chunk)
    raise ValueError(f"unknown rank engine {engine!r}")
