"""The hybrid MSD radix sort (paper §4) as a composable JAX transform.

Algorithm (faithful to §4.1–§4.5):

  * counting-sort passes proceed from the most-significant d-bit digit; each
    pass partitions every *active* bucket (size > ∂̂) into up to r = 2^d
    sub-buckets (R2),
  * runs of tiny sub-buckets are merged while their total stays below ∂ (R3),
  * buckets at or below ∂̂ become *done* and are finished by a single local
    sort that touches device memory only twice (R1),
  * the loop exits as soon as no active bucket remains (data-dependent trip
    count — the "finish early" behaviour that yields the 4x uniform-input
    speedup) or when digits are exhausted,
  * all bookkeeping arrays are statically sized by the analytical model §4.5
    (see core.model) — the paper's bounds are what make the algorithm
    expressible under XLA's static shapes.

Bucket state is carried *per key* (segment ids + done flags), which is the
dense JAX analogue of the paper's block-assignment lists; ``core.plan`` owns
every derived descriptor (active segments, block tables, merge bookkeeping,
digit windows) so the three engines share one set of invariants.

Three interchangeable engines compute each pass (byte-identical outputs):

  * ``kernel``  — ONE fused Pallas launch per pass (``kernels.fused``):
    block-descriptor-driven tile partition + coalesced scatter of pass i
    fused with the digit histogram of pass i+1 (§4.2–§4.4), on ping-pong
    key/value buffers with donation.  Per pass the keys are read once and
    written once; only the very first pass pays an extra histogram sweep.
    Zero comparison sorts in the traced HLO.
  * ``argsort`` — two fused XLA stable sorts per pass; the CPU default.
  * ``scan``    — the O(n) chunked-histogram fallback from ``core.ranks``.

Entropy-adaptive schedule (``cfg.adaptive`` / the ``adaptive`` argument):

  * *static narrowing* — when the keys are concrete (not traced), one host
    OR/AND-reduce finds the globally live bit window [lo, hi): dead high
    bits (shared prefixes) and dead low bits never get a pass, so the
    schedule runs ⌈(hi - lo)/d⌉ passes instead of ⌈k/d⌉.  Traced keys keep
    the full window — narrowing never changes a compiled trace's shape.
  * *mid-sort elision* — the pass loop watches the histogram it already has
    (fused out of the previous scatter): when every active segment has a
    single occupied digit the pass's scatter is the identity, so the launch
    is skipped while the bookkeeping still advances.  The fused kernel
    histograms a second *lookahead* window (pass i+2) alongside pass i+1's
    so an elided pass leaves the next histogram in hand; elision therefore
    needs no extra key sweep and no extra launch — each elided pass is an
    elided ``pallas_call``.  All engines evaluate the identical predicate,
    keeping outputs and ``SortStats`` byte-identical across engines.
  * *compressed keys* (``hybrid_sort(compress=True)``, concrete keys only)
    — ``bijection.CompressionPlan`` packs out every dead bit column and
    sorts the narrowed carrier (uint64 keys with <= 32 live bits sort as
    uint32), inverting exactly afterwards.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bijection, model, plan
from repro.core.ranks import stable_partition_dest
from repro.kernels import fused
from repro.kernels.ops import (apply_run_copies, local_sort_class_plan,
                               segmented_local_sort)


class SortStats(NamedTuple):
    counting_passes: jnp.ndarray   # number of executed counting-sort passes
    used_local_sort: jnp.ndarray   # bool: did the final local sort run
    num_segments: jnp.ndarray      # segments at exit (I3 bound check)
    max_segment: jnp.ndarray       # largest segment at exit
    elided_passes: jnp.ndarray = jnp.int32(0)  # adaptive: passes advanced
                                               # with no launch/partition


def live_bit_window(ukeys) -> tuple:
    """Static live-bit window [lo, hi) of a host-resident ordered-bits array.

    One OR-reduce and one AND-reduce: bits where they agree are globally
    constant and carry no ordering information.  Returns ``(lo, hi)`` as
    Python ints — ``(0, 0)`` when every key is equal (or the array is
    empty), so a narrowed schedule plans zero passes.
    """
    ukeys = np.asarray(ukeys).reshape(-1)
    if ukeys.size == 0:
        return 0, 0
    orv = int(np.bitwise_or.reduce(ukeys))
    andv = int(np.bitwise_and.reduce(ukeys))
    live = orv ^ andv
    if not live:
        return 0, 0
    return (live & -live).bit_length() - 1, live.bit_length()


def _skip_predicate(hist, nxt_valid, p, nd):
    """The shared elision predicate (identical across all engines).

    A pass is elidable when every active segment has at most one occupied
    digit — its stable scatter is then the identity permutation.  It may
    actually be skipped only when the NEXT pass's histogram is already in
    hand (``nxt_valid``: the previous pass executed with lookahead) or when
    this is the final pass (the loop exits; no next histogram is needed).
    """
    single = jnp.all(jnp.sum(hist > 0, axis=1) <= 1)
    return single & (nxt_valid | (p >= nd - 1))


def _counting_pass_jnp(state, *, k, d, lo, a_max, nd, cfg, engine, adaptive):
    """One counting pass, jnp engines: XLA stable sorts or the scan ranks."""
    ukeys, vals, seg_id, done, nxt_valid, p, p_exec, n_eld = state
    n = ukeys.shape[0]
    r = 1 << d
    active = ~done
    asegs = plan.active_segments(seg_id, done, a_max)
    asid = asegs.index

    digit = plan.digit_at(ukeys, p, k, d, lo=lo)
    # (a, digit) histogram — only active keys contribute (M2 of the model)
    idx = jnp.where(active, asid * r + digit, 0)
    hist = jnp.zeros((a_max * r,), jnp.int32).at[idx].add(
        active.astype(jnp.int32)).reshape(a_max, r)

    def partition():
        # destination permutation: stable partition by (active segment,
        # digit); done keys carry a +inf-like composite and stay in place.
        sentinel = jnp.int32(a_max * r)
        composite = jnp.where(active, asid * r + digit, sentinel)
        dest0 = stable_partition_dest(composite, a_max * r + 1, engine=engine)
        done_rank = stable_partition_dest(done.astype(jnp.int32), 2,
                                          engine=engine)
        slots = jnp.zeros((n,), jnp.int32).at[done_rank].set(
            jnp.arange(n, dtype=jnp.int32))   # active slots asc, then done asc
        dest = slots[dest0]
        new_keys = jnp.zeros_like(ukeys).at[dest].set(ukeys)
        new_vals = jax.tree.map(lambda v: jnp.zeros_like(v).at[dest].set(v),
                                vals)
        return new_keys, new_vals

    if adaptive:
        skip = _skip_predicate(hist, nxt_valid, p, nd)
        new_keys, new_vals = lax.cond(skip, lambda: (ukeys, vals), partition)
        nvalid = (~skip) & (p + 2 < nd)
        p_exec = p_exec + (~skip).astype(jnp.int32)
        n_eld = n_eld + skip.astype(jnp.int32)
    else:
        new_keys, new_vals = partition()
        nvalid = nxt_valid
        p_exec = p_exec + 1

    # bucket bookkeeping: merged-group starts (R3) become the new boundaries
    gstart, gdone = plan.merge_rows(hist, cfg.local_threshold,
                                    cfg.merge_threshold)
    excl = jnp.cumsum(hist, axis=1) - hist
    dest_base = asegs.base[:, None] + excl                    # (a_max, r)
    new_seg, new_done = plan.apply_pass_bookkeeping(
        seg_id, done, asegs, hist, gstart, gdone, dest_base)
    return (new_keys, new_vals, new_seg, new_done, nvalid, p + 1, p_exec,
            n_eld)


def _counting_pass_fused(state, *, k, d, lo, a_max, g_max, n, nd, cfg,
                         adaptive, interpret):
    """One counting pass, kernel engine: a single fused Pallas launch.

    ``state`` carries the ping-pong buffers, the dense bucket state and the
    per-active-segment histogram of THIS pass's digit — fused out of the
    previous pass's scatter (§4.3; the first pass's comes from the prologue
    sweep).  The launch reads the keys once and writes them once.  Under
    the adaptive schedule the state also carries the *lookahead* histogram
    (next pass's window, fused out of the same scatter) and its validity
    flag; when the shared skip predicate fires, the launch is elided — the
    identity scatter never runs, the ping-pong buffers stand still, and
    only the bookkeeping advances.  Exactly one ``pallas_call`` sits in the
    loop body either way (the elided branch contains none), which is what
    keeps the launch census per EXECUTED pass.
    """
    (ck, cv, ak, av, seg_id, done, hist_cur, hist_nxt, nxt_valid, p, p_exec,
     n_eld) = state
    r = 1 << d
    asegs = plan.active_segments(seg_id, done, a_max)
    gstart, gdone = plan.merge_rows(hist_cur, cfg.local_threshold,
                                    cfg.merge_threshold)
    excl = jnp.cumsum(hist_cur, axis=1) - hist_cur
    dest_base = asegs.base[:, None] + excl                    # (a_max, r)
    nsid = plan.next_active_table(hist_cur, cfg.local_threshold, a_max)
    new_seg, new_done = plan.apply_pass_bookkeeping(
        seg_id, done, asegs, hist_cur, gstart, gdone, dest_base)

    def launch():
        blocks = plan.make_region_blocks(asegs.base, asegs.size, n, cfg.kpb,
                                         g_max, batch=cfg.step_batch)
        sc = plan.digit_window(p, k, d, lo=lo)
        if adaptive:
            nk, nv, h1, h2 = fused.fused_counting_pass(
                ck, cv, ak, av, sc, *blocks, dest_base, nsid,
                kpb=cfg.kpb, r=r, a_max=a_max, n=n, interpret=interpret,
                lookahead=True)
            return (nk, nv, ck, cv, h1.reshape(a_max, r),
                    h2.reshape(a_max, r), p + 2 < nd, p_exec + 1, n_eld)
        nk, nv, h1 = fused.fused_counting_pass(
            ck, cv, ak, av, sc, *blocks, dest_base, nsid,
            kpb=cfg.kpb, r=r, a_max=a_max, n=n, interpret=interpret)
        return (nk, nv, ck, cv, h1.reshape(a_max, r), hist_nxt, nxt_valid,
                p_exec + 1, n_eld)

    if adaptive:
        def elide():
            # identity scatter: buffers and positions stand still; the
            # lookahead histogram becomes the next pass's current histogram
            return (ck, cv, ak, av, hist_nxt, jnp.zeros_like(hist_nxt),
                    jnp.bool_(False), p_exec, n_eld + 1)
        skip = _skip_predicate(hist_cur, nxt_valid, p, nd)
        nk, nv, nak, nav, h_cur, h_nxt, nvalid, npe, nne = lax.cond(
            skip, elide, launch)
    else:
        nk, nv, nak, nav, h_cur, h_nxt, nvalid, npe, nne = launch()
    # flip: the freshly written buffers become current, the old ones the
    # donation targets of the next pass (an elided pass flips nothing)
    return (nk, nv, nak, nav, new_seg, new_done, h_cur, h_nxt, nvalid,
            p + 1, npe, nne)


def _local_sort(ukeys, vals, seg_id, done):
    """Finish done buckets in one read+write: sort by (bucket, remaining key).

    Keys within a bucket share their already-processed digit prefix, so
    ordering by the full key equals ordering by the remaining digits — this is
    the LSD-on-remaining-digits local sort of §4.1, realised as a segmented
    sort (the Pallas bitonic kernel is the on-TPU tile engine for it).

    Only *done* buckets sort (masked key + stable lexsort keeps the rest in
    place).  At genuine digit exhaustion non-done buckets hold equal keys so
    this changes nothing; under ``max_passes`` truncation it keeps every
    engine's output identical: partition-ordered, unfinished buckets as-is.
    """
    perm = jnp.lexsort((jnp.where(done, ukeys, jnp.zeros_like(ukeys)), seg_id))
    return ukeys[perm], jax.tree.map(lambda v: v[perm], vals)


def _local_sort_kernel(ukeys, vals, seg_id, done, *, s_max, row_len, classes,
                       interpret):
    """Kernel-engined local sort: done buckets gather into sentinel-padded
    rows binned by power-of-two size class (R1 guarantees the widest class
    is next_pow2(∂̂); §4.2's local sort configurations keep tiny buckets off
    worst-case padding), the stable bitonic kernel sorts each class's rows
    by (key, position), and the run copies scatter the sorted prefixes back.
    Non-done buckets at digit exhaustion hold equal keys, so skipping them
    matches the jnp engines' stable lexsort exactly.
    """
    n = ukeys.shape[0]
    boundary = jnp.concatenate([jnp.ones((1,), bool),
                                seg_id[1:] != seg_id[:-1]])
    starts = jnp.nonzero(boundary, size=s_max, fill_value=n)[0].astype(jnp.int32)
    ends = jnp.concatenate([starts[1:], jnp.array([n], jnp.int32)])
    sizes = ends - starts                                     # 0 on padding rows
    sortable = done[jnp.clip(starts, 0, n - 1)] & (starts < n)
    src, dst = segmented_local_sort(ukeys, starts, sizes, sortable, row_len,
                                    interpret=interpret, classes=classes)
    return apply_run_copies(src, dst, (ukeys, vals))


def _local_row_len(n: int, cfg: model.SortConfig) -> int:
    """Bitonic row width: next power of two covering a done bucket (<= ∂̂)."""
    cap = max(1, min(cfg.local_threshold, n))
    return 1 << (cap - 1).bit_length()


def local_sort_classes(n: int, cfg: model.SortConfig):
    """Static size-class plan of the kernel engine's local sort: the (L,
    rows) bins of ``ops.local_sort_class_plan`` for this (n, cfg) — also the
    source of truth for how many bitonic launches the finish stage traces
    (one per class, the launch-census tests pin ``2 + len(...)`` total).
    """
    return local_sort_class_plan(n, _local_row_len(n, cfg),
                                 model.max_total_buckets(n, cfg))


@functools.partial(jax.jit, static_argnames=("cfg", "k", "return_stats",
                                             "max_passes", "engine",
                                             "interpret", "lo", "adaptive"))
def _hybrid_sort_bits(ukeys, vals, cfg: model.SortConfig, k: int,
                      return_stats: bool, max_passes: Optional[int] = None,
                      engine: str = "argsort", interpret: bool = True,
                      lo: int = 0, adaptive: bool = False):
    n = ukeys.shape[0]
    d = cfg.d
    r = 1 << d
    nd = model.num_digits(max(k - lo, 0), d)   # passes over the live window
    if max_passes is not None:
        nd = min(nd, max_passes)
    a_max = model.max_active_buckets(n, cfg)

    done0 = jnp.full((n,), n <= cfg.local_threshold)
    seg0 = jnp.zeros((n,), jnp.int32)
    nxt_valid0 = jnp.bool_(False)
    z = jnp.int32(0)

    if engine == "kernel":
        g_max = plan.max_region_blocks(n, cfg.kpb, a_max)
        leaves, treedef = jax.tree.flatten(vals)
        (ck, cv), (ak, av) = fused.make_ping_pong(ukeys, leaves, cfg.kpb)
        # the one unfused sweep of the sort: pass 0's histogram (§4.3)
        w0 = min(d, max(k - lo, 1))
        seg_hist0 = fused.initial_histogram(ck, n, max(k - w0, 0), w0, r,
                                            a_max, cfg.kpb,
                                            interpret=interpret)

        def cond(state):
            done, p = state[5], state[9]
            return (p < nd) & jnp.any(~done)

        body = functools.partial(_counting_pass_fused, k=k, d=d, lo=lo,
                                 a_max=a_max, g_max=g_max, n=n, nd=nd,
                                 cfg=cfg, adaptive=adaptive,
                                 interpret=interpret)
        (ck, cv, ak, av, seg, done, _, _, _, p, p_exec, n_eld) = \
            lax.while_loop(cond, body,
                           (ck, cv, ak, av, seg0, done0, seg_hist0,
                            jnp.zeros_like(seg_hist0), nxt_valid0,
                            z, z, z))
        ukeys = ck[:n]
        vals = jax.tree.unflatten(treedef, [v[:n] for v in cv])
    else:
        def cond(state):
            done, p = state[3], state[5]
            return (p < nd) & jnp.any(~done)

        body = functools.partial(_counting_pass_jnp, k=k, d=d, lo=lo,
                                 a_max=a_max, nd=nd, cfg=cfg, engine=engine,
                                 adaptive=adaptive)
        ukeys, vals, seg, done, _, p, p_exec, n_eld = lax.while_loop(
            cond, body, (ukeys, vals, seg0, done0, nxt_valid0, z, z, z))

    needs_local = jnp.any(done)
    if engine == "kernel":
        finish = functools.partial(
            _local_sort_kernel, s_max=model.max_total_buckets(n, cfg),
            row_len=_local_row_len(n, cfg),
            classes=local_sort_classes(n, cfg), interpret=interpret)
    else:
        finish = _local_sort
    ukeys, vals = lax.cond(needs_local, finish,
                           lambda k_, v_, s_, d_: (k_, v_),
                           ukeys, vals, seg, done)
    if not return_stats:
        return ukeys, vals, None
    sizes = jnp.bincount(seg, length=n if n else 1)
    stats = SortStats(counting_passes=p_exec, used_local_sort=needs_local,
                      num_segments=seg[-1] + 1 if n else jnp.int32(0),
                      max_segment=sizes.max(), elided_passes=n_eld)
    return ukeys, vals, stats


def hybrid_sort(keys: jnp.ndarray, values: Any = None,
                cfg: Optional[model.SortConfig] = None,
                return_stats: bool = False, max_passes: Optional[int] = None,
                engine: Optional[str] = None, interpret: Optional[bool] = None,
                adaptive: Optional[bool] = None, compress: bool = False):
    """Sort ``keys`` (any supported primitive dtype) with the hybrid radix sort.

    ``values`` is an optional array or pytree of arrays permuted alongside the
    keys (decomposed key-value layout, §4.6).  Pair movement is consistent but
    — by the paper's central design choice — NOT stable across equal keys.

    ``engine`` selects the per-pass partition engine: ``"kernel"`` (ONE fused
    Pallas launch per counting pass — partition + scatter + next-pass
    histogram on donated ping-pong buffers — plus the bitonic local sort),
    ``"argsort"`` (fused XLA stable sorts), or ``"scan"`` (the O(n) chunked
    jnp fallback).  ``None`` defers to ``cfg.rank_engine`` (``"auto"`` by
    default); ``"auto"`` resolves per backend with the hardware demotion
    rule of ``core.plan.resolve_pass_engine`` — the fused ``kernel`` engine
    wherever Pallas runs in interpret mode, ``argsort`` on compiled
    hardware until the fused kernel's Mosaic lowering lands (an explicit
    ``engine="kernel"`` is always honoured).  All engines produce
    byte-identical output.  ``interpret`` forces Pallas interpret mode (on
    by default off-TPU).

    ``adaptive`` enables the entropy-adaptive schedule (``None`` defers to
    ``cfg.adaptive``, on by default): concrete keys get a statically
    narrowed live-bit window, and single-digit passes are elided mid-sort
    (bookkeeping advances; no launch happens).  Every pass of the schedule
    is a stable partition, so the output is byte-identical with the
    adaptive schedule on or off.  ``compress=True`` (concrete keys only)
    additionally bit-packs out all dead key columns and sorts the narrowed
    carrier, inverting the packing exactly afterwards.

    Returns ``sorted_keys``, or ``(sorted_keys, permuted_values)`` if values
    were given; append ``stats`` when ``return_stats``.
    """
    if keys.ndim != 1:
        raise ValueError("hybrid_sort expects a 1-D key array")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = bijection.key_bits(keys.dtype)
    if k > 32 and not jax.config.jax_enable_x64:
        raise RuntimeError("64-bit keys require jax_enable_x64")
    cfg = cfg or model.default_config(k // 8)
    if adaptive is None:
        adaptive = cfg.adaptive
    # explicit argument > cfg.rank_engine > backend default (with the
    # interpret-only demotion of auto-resolved "kernel", see core.plan)
    engine = plan.resolve_pass_engine(
        engine if engine is not None else cfg.rank_engine, interpret)
    n = keys.shape[0]
    if n == 0:
        out = (keys, values) if values is not None else keys
        if return_stats:
            z = jnp.int32(0)
            return (*((out,) if values is None else out),
                    SortStats(z, jnp.bool_(False), z, z, z))
        return out

    concrete = not isinstance(keys, jax.core.Tracer)
    cplan = None
    lo, hi = 0, k
    if compress:
        if not concrete:
            raise ValueError("compress=True requires concrete (non-traced) "
                             "keys: the packing plan is data-dependent")
        cplan = bijection.compression_plan_np(
            bijection.to_ordered_bits_np(np.asarray(keys)))
        ukeys = bijection.pack_ordered_bits(bijection.to_ordered_bits(keys),
                                            cplan)
        # every packed column is live by construction; sort just those bits
        lo, hi = 0, cplan.packed_bits
    else:
        ukeys = bijection.to_ordered_bits(keys)
        if adaptive and concrete:
            lo, hi = live_bit_window(bijection.to_ordered_bits_np(
                np.asarray(keys)))

    vals = values if values is not None else ()
    ukeys, vals, stats = _hybrid_sort_bits(ukeys, vals, cfg, hi, return_stats,
                                           max_passes, engine, interpret,
                                           lo=lo, adaptive=adaptive)
    if cplan is not None:
        ukeys = bijection.unpack_ordered_bits(ukeys, cplan)
    out_keys = bijection.from_ordered_bits(ukeys, keys.dtype)
    if values is None:
        return (out_keys, stats) if return_stats else out_keys
    return (out_keys, vals, stats) if return_stats else (out_keys, vals)


# --- contract declaration (verified by repro.analysis; see analysis/contracts)
# Formulas are symbolic in the structural parameters the analyzer derives per
# (n, cfg): classes = len(local_sort_classes(n, cfg)), passes = ⌈k/d⌉ nominal
# schedule slots, n_pad = fused.pad_length(n, cfg.kpb), kb/vb = key/value
# bytes, vals = payload leaves, g_max/B = descriptor rows / super-step width.
ANALYSIS_CONTRACT = {
    "entry": "repro.core.hybrid.hybrid_sort",
    "census": {
        "launch_total": "2 + classes",
        "while_body_launches": "[1]",
        "fused_grid": "ceil_div(g_max, B)",
    },
    "sort_free": True,
    "donation": {"_fused_pass_kernel": "1 + vals"},
    "transfer": {
        "sweep_kernels": ["_hist_kernel", "_fused_pass_kernel"],
        "bytes": "(2 * passes + 1) * n_pad * kb + 2 * passes * n_pad * vb",
    },
}
