"""The hybrid MSD radix sort (paper §4) as a composable JAX transform.

Algorithm (faithful to §4.1–§4.5):

  * counting-sort passes proceed from the most-significant d-bit digit; each
    pass partitions every *active* bucket (size > ∂̂) into up to r = 2^d
    sub-buckets (R2),
  * runs of tiny sub-buckets are merged while their total stays below ∂ (R3),
  * buckets at or below ∂̂ become *done* and are finished by a single local
    sort that touches device memory only twice (R1),
  * the loop exits as soon as no active bucket remains (data-dependent trip
    count — the "finish early" behaviour that yields the 4x uniform-input
    speedup) or when digits are exhausted,
  * all bookkeeping arrays are statically sized by the analytical model §4.5
    (see core.model) — the paper's bounds are what make the algorithm
    expressible under XLA's static shapes.

Bucket state is carried *per key* (segment ids + done flags), which is the
dense JAX analogue of the paper's block-assignment lists: monotone seg ids
over positions encode exactly {b_id, b_offs}, and tile-aligned views of them
drive the Pallas kernels' scalar prefetch.

Three interchangeable engines compute each pass's permutation (byte-identical
outputs, see ``core.ranks``):

  * ``kernel``  — the paper's pipeline on Pallas kernels: block-assignment
    descriptors (§4.2) feed one constant-size multisplit launch over all
    active buckets (tile histogram → per-segment scan → coalesced run
    copies, §4.3–§4.4), and done buckets finish through the padded
    segmented bitonic local sort.  Zero comparison sorts in the traced HLO.
  * ``argsort`` — two fused XLA stable sorts per pass; the CPU default.
  * ``scan``    — the O(n) chunked-histogram fallback from ``core.ranks``.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bijection, model
from repro.core.ranks import resolve_engine, stable_partition_dest
from repro.kernels.ops import (apply_run_copies, segmented_kernel_pass,
                               segmented_local_sort)


class SortStats(NamedTuple):
    counting_passes: jnp.ndarray   # number of executed counting-sort passes
    used_local_sort: jnp.ndarray   # bool: did the final local sort run
    num_segments: jnp.ndarray      # segments at exit (I3 bound check)
    max_segment: jnp.ndarray       # largest segment at exit


def _digit_at(ukeys: jnp.ndarray, pass_idx, k: int, d: int) -> jnp.ndarray:
    """MSD digit for pass ``pass_idx`` (0 = most significant); handles k % d != 0."""
    udt = ukeys.dtype
    hi = k - pass_idx * d
    width = jnp.minimum(d, hi)
    lo = (hi - width).astype(udt)
    mask = ((jnp.array(1, udt) << width.astype(udt)) - 1).astype(udt)
    return ((ukeys >> lo) & mask).astype(jnp.int32)


def _merge_rows(hist: jnp.ndarray, local_threshold: int, merge_threshold: int):
    """Apply R3 to each active bucket's sub-bucket size row.

    Returns (group_start, group_done): (A, r) bools — whether sub-bucket v
    starts a new (merged) bucket, and whether that bucket is finished (<= ∂̂).
    """
    def row(s_row):
        def step(carry, s):
            acc, gid = carry
            big = s > local_threshold
            extend = (s == 0) | ((~big) & (acc + s < merge_threshold))
            ngid = jnp.where(extend, gid, gid + 1)
            nacc = jnp.where(extend, acc + s,
                             jnp.where(big, merge_threshold, s))
            return (nacc, ngid), (~extend, ~big)
        (_, _), (gstart, gdone) = lax.scan(
            step, (jnp.int32(merge_threshold), jnp.int32(0)), s_row)
        return gstart, gdone
    return jax.vmap(row)(hist)


def _counting_pass(ukeys, vals, seg_id, done, pass_idx, *, k, d, a_max, g_max,
                   cfg, engine, interpret):
    """One counting-sort pass over all active buckets simultaneously."""
    n = ukeys.shape[0]
    r = 1 << d
    active = ~done
    boundary = jnp.concatenate([jnp.ones((1,), bool),
                                seg_id[1:] != seg_id[:-1]])
    astart = boundary & active
    asid = jnp.cumsum(astart.astype(jnp.int32)) - 1          # active-segment index
    active_base = jnp.nonzero(astart, size=a_max, fill_value=n)[0].astype(jnp.int32)

    if engine == "kernel":
        # Pre-shift so the kernels extract the pass's digit at a *static*
        # position (low d bits).  On a partial-width last pass the extra high
        # bits are the bucket's shared, already-processed prefix — constant
        # within every segment, so the partition and the (column-shifted)
        # merge bookkeeping are unchanged.
        udt = ukeys.dtype
        hi = k - pass_idx * d
        lo = jnp.maximum(hi - d, 0).astype(udt)
        shifted = ukeys >> lo
        digit = (shifted & jnp.array(r - 1, udt)).astype(jnp.int32)
        asize = jnp.zeros((a_max,), jnp.int32).at[
            jnp.where(active, asid, a_max)].add(1, mode="drop")
        src, dst, hist = segmented_kernel_pass(
            shifted, active_base, asize, d, cfg.kpb, g_max,
            interpret=interpret)
    else:
        digit = _digit_at(ukeys, pass_idx, k, d)
        # (a, digit) histogram — only active keys contribute (M2 of the model)
        idx = jnp.where(active, asid * r + digit, 0)
        hist = jnp.zeros((a_max * r,), jnp.int32).at[idx].add(
            active.astype(jnp.int32)).reshape(a_max, r)

        # destination permutation: stable partition by (active segment, digit);
        # done keys carry a +inf-like composite and stay in place.
        sentinel = jnp.int32(a_max * r)
        composite = jnp.where(active, asid * r + digit, sentinel)
        dest0 = stable_partition_dest(composite, a_max * r + 1, engine=engine)
        done_rank = stable_partition_dest(done.astype(jnp.int32), 2,
                                          engine=engine)
        slots = jnp.zeros((n,), jnp.int32).at[done_rank].set(
            jnp.arange(n, dtype=jnp.int32))   # active slots asc, then done asc
        dest = slots[dest0]

        new_keys = jnp.zeros_like(ukeys).at[dest].set(ukeys)
        new_vals = jax.tree.map(lambda v: jnp.zeros_like(v).at[dest].set(v),
                                vals)

    # bucket bookkeeping: merged-group starts (R3) become the new boundaries
    gstart, gdone = _merge_rows(hist, cfg.local_threshold, cfg.merge_threshold)
    excl = jnp.cumsum(hist, axis=1) - hist
    dest_base = active_base[:, None] + excl                   # (a_max, r)

    nb = jnp.zeros((n,), bool)
    keep = boundary & done                                    # done buckets persist in place
    nb = nb.at[jnp.where(keep, jnp.arange(n), n)].set(True, mode="drop")
    nb = nb.at[jnp.where(gstart.reshape(-1), dest_base.reshape(-1), n)].set(True, mode="drop")
    nb = nb.at[0].set(True)
    new_seg = (jnp.cumsum(nb.astype(jnp.int32)) - 1)

    key_gdone = gdone.reshape(-1)[jnp.where(active, asid * r + digit, 0)]
    if engine == "kernel":
        # run copies: done keys keep their slots, active slots are overwritten
        new_keys, new_vals = apply_run_copies(src, dst, (ukeys, vals))
        new_done = done.at[dst].set(key_gdone[jnp.clip(src, 0, n - 1)],
                                    mode="drop")
    else:
        new_done = jnp.zeros((n,), bool).at[dest].set(
            jnp.where(active, key_gdone, True))
    return new_keys, new_vals, new_seg, new_done


def _local_sort(ukeys, vals, seg_id, done):
    """Finish done buckets in one read+write: sort by (bucket, remaining key).

    Keys within a bucket share their already-processed digit prefix, so
    ordering by the full key equals ordering by the remaining digits — this is
    the LSD-on-remaining-digits local sort of §4.1, realised as a segmented
    sort (the Pallas bitonic kernel is the on-TPU tile engine for it).

    Only *done* buckets sort (masked key + stable lexsort keeps the rest in
    place).  At genuine digit exhaustion non-done buckets hold equal keys so
    this changes nothing; under ``max_passes`` truncation it keeps every
    engine's output identical: partition-ordered, unfinished buckets as-is.
    """
    perm = jnp.lexsort((jnp.where(done, ukeys, jnp.zeros_like(ukeys)), seg_id))
    return ukeys[perm], jax.tree.map(lambda v: v[perm], vals)


def _local_sort_kernel(ukeys, vals, seg_id, done, *, s_max, row_len, interpret):
    """Kernel-engined local sort: done buckets gather into sentinel-padded
    (S, L) rows (R1 guarantees L <= next_pow2(∂̂)), the stable bitonic kernel
    sorts each row by (key, position), and the run copies scatter the sorted
    prefixes back.  Non-done buckets at digit exhaustion hold equal keys, so
    skipping them matches the jnp engines' stable lexsort exactly.
    """
    n = ukeys.shape[0]
    boundary = jnp.concatenate([jnp.ones((1,), bool),
                                seg_id[1:] != seg_id[:-1]])
    starts = jnp.nonzero(boundary, size=s_max, fill_value=n)[0].astype(jnp.int32)
    ends = jnp.concatenate([starts[1:], jnp.array([n], jnp.int32)])
    sizes = ends - starts                                     # 0 on padding rows
    sortable = done[jnp.clip(starts, 0, n - 1)] & (starts < n)
    src, dst = segmented_local_sort(ukeys, starts, sizes, sortable, row_len,
                                    interpret=interpret)
    return apply_run_copies(src, dst, (ukeys, vals))


def _local_row_len(n: int, cfg: model.SortConfig) -> int:
    """Bitonic row width: next power of two covering a done bucket (<= ∂̂)."""
    cap = max(1, min(cfg.local_threshold, n))
    return 1 << (cap - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("cfg", "k", "return_stats",
                                             "max_passes", "engine",
                                             "interpret"))
def _hybrid_sort_bits(ukeys, vals, cfg: model.SortConfig, k: int,
                      return_stats: bool, max_passes: Optional[int] = None,
                      engine: str = "argsort", interpret: bool = True):
    n = ukeys.shape[0]
    d = cfg.d
    nd = model.num_digits(k, d)
    if max_passes is not None:
        nd = min(nd, max_passes)
    a_max = model.max_active_buckets(n, cfg)
    g_max = model.max_blocks(n, cfg)

    done0 = jnp.full((n,), n <= cfg.local_threshold)
    seg0 = jnp.zeros((n,), jnp.int32)

    def cond(state):
        _, _, _, done, p = state
        return (p < nd) & jnp.any(~done)

    def body(state):
        ukeys, vals, seg, done, p = state
        ukeys, vals, seg, done = _counting_pass(
            ukeys, vals, seg, done, p, k=k, d=d, a_max=a_max, g_max=g_max,
            cfg=cfg, engine=engine, interpret=interpret)
        return ukeys, vals, seg, done, p + 1

    ukeys, vals, seg, done, p = lax.while_loop(
        cond, body, (ukeys, vals, seg0, done0, jnp.int32(0)))

    needs_local = jnp.any(done)
    if engine == "kernel":
        finish = functools.partial(
            _local_sort_kernel, s_max=model.max_total_buckets(n, cfg),
            row_len=_local_row_len(n, cfg), interpret=interpret)
    else:
        finish = _local_sort
    ukeys, vals = lax.cond(needs_local, finish,
                           lambda k_, v_, s_, d_: (k_, v_),
                           ukeys, vals, seg, done)
    if not return_stats:
        return ukeys, vals, None
    sizes = jnp.bincount(seg, length=n if n else 1)
    stats = SortStats(counting_passes=p, used_local_sort=needs_local,
                      num_segments=seg[-1] + 1 if n else jnp.int32(0),
                      max_segment=sizes.max())
    return ukeys, vals, stats


def hybrid_sort(keys: jnp.ndarray, values: Any = None,
                cfg: Optional[model.SortConfig] = None,
                return_stats: bool = False, max_passes: Optional[int] = None,
                engine: Optional[str] = None, interpret: Optional[bool] = None):
    """Sort ``keys`` (any supported primitive dtype) with the hybrid radix sort.

    ``values`` is an optional array or pytree of arrays permuted alongside the
    keys (decomposed key-value layout, §4.6).  Pair movement is consistent but
    — by the paper's central design choice — NOT stable across equal keys.

    ``engine`` selects the per-pass partition engine: ``"kernel"`` (the Pallas
    counting-pass pipeline — histogram, multisplit, run copies — plus the
    bitonic local sort), ``"argsort"`` (fused XLA stable sorts), or ``"scan"``
    (the O(n) chunked jnp fallback).  ``None`` defers to ``cfg.rank_engine``
    (``"auto"`` by default), and ``"auto"`` picks the backend default:
    ``kernel`` on TPU, ``argsort`` elsewhere.  All engines produce
    byte-identical output.  ``interpret`` forces Pallas interpret mode (on by
    default off-TPU).

    Returns ``sorted_keys``, or ``(sorted_keys, permuted_values)`` if values
    were given; append ``stats`` when ``return_stats``.
    """
    if keys.ndim != 1:
        raise ValueError("hybrid_sort expects a 1-D key array")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = bijection.key_bits(keys.dtype)
    if k > 32 and not jax.config.jax_enable_x64:
        raise RuntimeError("64-bit keys require jax_enable_x64")
    cfg = cfg or model.default_config(k // 8)
    # explicit argument > cfg.rank_engine > backend default
    engine = resolve_engine(engine if engine is not None else cfg.rank_engine)
    n = keys.shape[0]
    if n == 0:
        out = (keys, values) if values is not None else keys
        if return_stats:
            z = jnp.int32(0)
            return (*((out,) if values is None else out),
                    SortStats(z, jnp.bool_(False), z, z))
        return out

    ukeys = bijection.to_ordered_bits(keys)
    vals = values if values is not None else ()
    ukeys, vals, stats = _hybrid_sort_bits(ukeys, vals, cfg, k, return_stats,
                                           max_passes, engine, interpret)
    out_keys = bijection.from_ordered_bits(ukeys, keys.dtype)
    if values is None:
        return (out_keys, stats) if return_stats else out_keys
    return (out_keys, vals, stats) if return_stats else (out_keys, vals)
