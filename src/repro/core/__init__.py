"""repro.core — the paper's contribution: a hybrid MSD radix sort for TPUs.

Public API:
  hybrid_sort        — §4: the memory-bandwidth-efficient hybrid radix sort
  lsd_sort           — §3 baseline (CUB analogue, stable LSD passes)
  SortConfig         — tuning knobs (Table 3 defaults)
  counting_partition — single counting-sort pass (MoE dispatch building block)
  segmented_sort     — batched independent sorts
  make_distributed_sort — §5: multi-chip pipelined sort (shard_map; sample-
                       sort shard exchange with KV payloads and bounded
                       splitter-refinement retries on capacity overflow)
  DistStats          — per-shard exchange ledger (attempts, residual
                       overflow, valid prefix length, peak received load)
  valid_concat       — host helper: shard outputs -> global sorted sequence
  oocsort            — §5: out-of-core pipelined sort (chunked device runs
                       under double-buffered staging + streaming k-way
                       merge; spill_budget_bytes bounds device memory by
                       streaming host-resident runs through device slabs;
                       faults=/retry=/checkpoint_dir=/resume_from= give it
                       a failure story — injected faults, bounded retries,
                       a degradation ladder and round-granular resume)
  FaultPolicy        — deterministic seed-driven fault injection for oocsort
  RetryPolicy        — bounded retries with capped backoff, ledger-tracked
"""
from repro.core.distributed import (DistStats, make_distributed_sort,
                                    valid_concat)
from repro.core.bijection import (to_ordered_bits, from_ordered_bits,
                                  from_ordered_bits_np, to_ordered_bits_np,
                                  key_bits)
from repro.core.faults import (FAULT_SITES, ChecksumError, FatalFault,
                               FaultPolicy, RetriesExhausted, RetryPolicy,
                               host_checksum)
from repro.core.hybrid import hybrid_sort, SortStats
from repro.core.lsd import lsd_sort
from repro.core.model import (SortConfig, default_config, memory_budget,
                              pass_counts, expected_speedup)
from repro.core.outofcore import oocsort, OocStats
from repro.core.ranks import ENGINES, resolve_engine

__all__ = [
    "hybrid_sort", "lsd_sort", "SortStats", "SortConfig", "default_config",
    "memory_budget", "pass_counts", "expected_speedup",
    "to_ordered_bits", "from_ordered_bits", "from_ordered_bits_np",
    "to_ordered_bits_np", "key_bits",
    "oocsort", "OocStats",
    "make_distributed_sort", "DistStats", "valid_concat",
    "FAULT_SITES", "FaultPolicy", "RetryPolicy", "FatalFault",
    "ChecksumError", "RetriesExhausted", "host_checksum",
    "ENGINES", "resolve_engine",
]
