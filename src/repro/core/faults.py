"""Fault injection, bounded retry, and checksum verification for oocsort.

The §5 out-of-core pipeline is exactly where real deployments fail: PCIe
transfers stall, device allocations OOM mid-round, host buffers rot while a
multi-round merge is in flight.  This module gives the out-of-core driver a
*deterministic* failure story in three layers:

  * :class:`FaultPolicy` — seed-driven injectable faults at every transfer
    and launch site of ``core.outofcore`` (``FAULT_SITES``).  Decisions are
    a pure function of ``(seed, site, per-site op index)``, so the same
    policy object replayed over the same driver schedule injects the same
    faults — the property the deterministic-replay tests pin.  Faults come
    in three kinds: ``transient`` (the op failed, retry it), ``fatal`` (the
    process dies mid-run — the kill half of the kill-and-resume test), and
    host-buffer ``corruption`` (a byte of a host-resident run is flipped in
    place, detectable only by checksum).
  * :class:`RetryPolicy` — bounded retries with capped exponential backoff.
    Every retry is ledger-tracked (:class:`FaultLedger`); when a site
    exhausts its retries the driver raises :class:`RetriesExhausted` and
    walks its degradation ladder instead of crashing.
  * :func:`host_checksum` — an xxhash-style (fast, non-cryptographic)
    per-buffer checksum computed at each host crossing.  The driver records
    a checksum when a run lands host-side and verifies it before the run is
    consumed, so silent corruption surfaces as :class:`ChecksumError`
    (recoverable from the last round checkpoint) instead of silently wrong
    output.

``guarded`` is the one chokepoint all sites go through: draw a fault
decision, account the attempt, back off, retry, escalate.  It is pure host
code wrapped *around* the jitted transfer/launch callables, so the Pallas
launch census of the guarded pipeline is byte-for-byte the census of the
unguarded one — retries re-invoke the same compiled function.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

import numpy as np

# every transfer/launch site the out-of-core driver guards, in pipeline
# order: the chunk phase's upload / sort launch / run download, the spill
# merge's strip upload / merge launch / strip download, and the host-buffer
# corruption pseudo-site (drawn once per round of freshly landed runs).
FAULT_SITES = ("chunk_upload", "sort_launch", "run_download",
               "slab_upload", "merge_launch", "slab_download",
               "host_corruption")


class TransientFault(RuntimeError):
    """An injected recoverable failure (stalled transfer, failed launch)."""


class FatalFault(RuntimeError):
    """An injected unrecoverable failure: models the process dying mid-run.

    Carries the :class:`FaultLedger` at the moment of death so tests (and
    post-mortems) can see what the run had survived before it was killed.
    """

    def __init__(self, site: str, ledger: Optional["FaultLedger"] = None):
        super().__init__(f"fatal injected fault at site {site!r}")
        self.site = site
        self.ledger = ledger


class ChecksumError(RuntimeError):
    """A host-resident buffer no longer matches its recorded checksum."""


class RetriesExhausted(RuntimeError):
    """A site kept failing past ``RetryPolicy.max_retries``.

    The out-of-core driver catches this and walks its degradation ladder
    (shrink the device slab, reduce the merge fan-in, re-chunk smaller);
    it only propagates when the ladder itself is exhausted.
    """

    def __init__(self, site: str, attempts: int):
        super().__init__(f"site {site!r} failed {attempts} consecutive "
                         f"attempts (retries exhausted)")
        self.site = site
        self.attempts = attempts


def host_checksum(arr: np.ndarray) -> int:
    """xxhash-style checksum of a host buffer: fast, deterministic, 32-bit.

    crc32 over the raw bytes, mixed with the dtype and shape so a buffer
    reinterpreted under another dtype does not collide.  Computed at each
    host crossing of the out-of-core pipeline (run downloads, checkpoint
    publishes) and verified before the buffer is consumed.
    """
    a = np.ascontiguousarray(arr)
    h = zlib.crc32(a.view(np.uint8).reshape(-1))
    h = zlib.crc32(f"{a.dtype.str}{a.shape}".encode(), h)
    return h & 0xFFFFFFFF


def tree_checksums(arrs: Iterable[np.ndarray]) -> Tuple[int, ...]:
    """Checksum a flat sequence of host buffers (a run's keys + leaves)."""
    return tuple(host_checksum(a) for a in arrs)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with capped exponential backoff.

    ``max_retries`` counts *re*-attempts: an op is tried at most
    ``1 + max_retries`` times before :class:`RetriesExhausted`.  Backoff for
    retry i sleeps ``min(backoff_cap_s, backoff_base_s * 2**i)`` seconds —
    the default base of 0 keeps the test/interpret loop instant while the
    formula (and the ledger accounting) stays the production shape.
    """
    max_retries: int = 3
    backoff_base_s: float = 0.0
    backoff_cap_s: float = 0.05

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    def backoff_s(self, attempt: int) -> float:
        if self.backoff_base_s <= 0.0:
            return 0.0
        return min(self.backoff_cap_s,
                   self.backoff_base_s * (2.0 ** attempt))


@dataclasses.dataclass
class FaultLedger:
    """Mutable per-run account of injected faults and recovery work.

    The out-of-core driver folds these counters into ``OocStats`` at the
    end of the run; the deterministic-replay tests assert two runs under
    the same seed produce identical ledgers.  ``retry_h2d_bytes`` /
    ``retry_d2h_bytes`` are the *extra* host-link bytes paid by failed
    transfer attempts (each failed attempt crossed the link before it was
    declared lost — the worst-case model), kept separate so the clean
    ``2·N·b·(1 + rounds)`` formulas stay exact.
    """
    retries: int = 0
    faults_injected: int = 0
    degradations: int = 0
    checksum_failures: int = 0
    rounds_checkpointed: int = 0
    retry_h2d_bytes: int = 0
    retry_d2h_bytes: int = 0

    @property
    def retry_link_bytes(self) -> int:
        return self.retry_h2d_bytes + self.retry_d2h_bytes


def _normalize_sites(mapping, what: str) -> Dict[str, frozenset]:
    out = {}
    for site, idxs in (mapping or {}).items():
        if site not in FAULT_SITES:
            raise ValueError(f"{what}: unknown fault site {site!r} "
                             f"(sites: {FAULT_SITES})")
        out[site] = frozenset(int(i) for i in idxs)
    return out


class FaultPolicy:
    """Deterministic, seed-driven fault points for the out-of-core driver.

    Three injection mechanisms compose (checked in this order per op):

      * ``fatal_at[site]``   — op indices that raise :class:`FatalFault`
        (the run dies; a checkpointed run resumes with ``resume_from``);
      * ``fail_at[site]``    — op indices that raise one
        :class:`TransientFault` each (bounded-retry fodder; N consecutive
        indices model N consecutive failures of one logical op);
      * ``rates[site]``      — a per-site fault probability; the decision
        for op i is a pure function of ``(seed, site, i)`` via a counter-
        keyed PRNG, so the schedule replays exactly under the same seed.

    Op indices are per-site visit counters that advance on every draw —
    including retries, so a ``fail_at`` entry of ``{0, 1}`` means "the
    first attempt and its first retry both fail".  Counters never reset
    (not across degradation restarts either), which is what lets a
    persistent fault burn through the retry budget and trigger the ladder.
    ``state()``/``load_state()`` expose the counters so a checkpoint
    manifest can persist mid-run fault-schedule position.

    ``host_corruption`` is a pseudo-site: when it fires,
    :meth:`maybe_corrupt` flips one deterministic byte of one host-resident
    run in place — detectable only by the driver's checksum verification.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Mapping[str, float]] = None,
                 fail_at: Optional[Mapping[str, Sequence[int]]] = None,
                 fatal_at: Optional[Mapping[str, Sequence[int]]] = None):
        self.seed = int(seed)
        self.rates = dict(rates or {})
        for site, rate in self.rates.items():
            if site not in FAULT_SITES:
                raise ValueError(f"rates: unknown fault site {site!r} "
                                 f"(sites: {FAULT_SITES})")
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"rates[{site!r}] must be in [0, 1]")
        self.fail_at = _normalize_sites(fail_at, "fail_at")
        self.fatal_at = _normalize_sites(fatal_at, "fatal_at")
        self._counts: Dict[str, int] = {}

    # -- deterministic decision machinery ----------------------------------

    def _uniform(self, site: str, index: int) -> float:
        seq = np.random.SeedSequence(
            [self.seed, zlib.crc32(site.encode()), index])
        return float(np.random.default_rng(seq).random())

    def draw(self, site: str) -> Optional[str]:
        """Advance ``site``'s op counter and return the injected fault kind
        for this op: ``None`` (clean), ``"transient"`` or ``"fatal"``."""
        if site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {site!r}")
        i = self._counts.get(site, 0)
        self._counts[site] = i + 1
        if i in self.fatal_at.get(site, ()):
            return "fatal"
        if i in self.fail_at.get(site, ()):
            return "transient"
        rate = self.rates.get(site, 0.0)
        if rate and self._uniform(site, i) < rate:
            return "transient"
        return None

    @property
    def corrupts(self) -> bool:
        """Whether this policy can ever fire the host_corruption site."""
        return bool(self.rates.get("host_corruption")
                    or self.fail_at.get("host_corruption")
                    or self.fatal_at.get("host_corruption"))

    def maybe_corrupt(self, arrays: Sequence[np.ndarray]) -> bool:
        """One host_corruption draw over a round's freshly landed runs.

        When it fires, flips one byte (xor 0xFF) of one non-empty buffer in
        place — buffer and byte chosen by the same counter-keyed PRNG, so
        the corruption replays deterministically.  Returns whether a byte
        was flipped.  Buffers must be writable (the driver owns its host
        runs).
        """
        i = self._counts.get("host_corruption", 0)
        kind = self.draw("host_corruption")
        if kind is None:
            return False
        live = [a for a in arrays if a.nbytes > 0]
        if not live:
            return False
        rng = np.random.default_rng(np.random.SeedSequence(
            [self.seed, zlib.crc32(b"host_corruption.pick"), i]))
        victim = live[int(rng.integers(len(live)))]
        byte = int(rng.integers(victim.nbytes))
        victim.view(np.uint8).reshape(-1)[byte] ^= 0xFF
        return True

    # -- resume support ----------------------------------------------------

    def state(self) -> Dict[str, int]:
        """Per-site op counters (JSON-serializable, for checkpoint manifests)."""
        return dict(self._counts)

    def load_state(self, state: Mapping[str, int]) -> None:
        """Restore op counters so a resumed run continues the schedule."""
        self._counts = {str(k): int(v) for k, v in state.items()}


def guarded(site: str, fn, *args,
            policy: Optional[FaultPolicy],
            retry: Optional[RetryPolicy],
            ledger: FaultLedger,
            cost_bytes: int = 0,
            direction: Optional[str] = None,
            **kwargs):
    """Run ``fn(*args, **kwargs)`` through one fault point with retries.

    Each attempt first asks ``policy`` for a fault decision at ``site``:

      * fatal     — raise :class:`FatalFault` immediately (no retry);
      * transient — account the lost attempt (``cost_bytes`` in
        ``direction`` — failed transfers still crossed the link) and retry
        after ``retry.backoff_s``; past ``retry.max_retries`` raise
        :class:`RetriesExhausted` for the driver's degradation ladder;
      * clean     — call ``fn`` and return its result.

    With ``policy=None`` this is a plain call: the guarded pipeline is
    byte- and launch-census-identical to the unguarded one.
    """
    if policy is None:
        return fn(*args, **kwargs)
    retry = retry or RetryPolicy()
    attempt = 0
    while True:
        kind = policy.draw(site)
        if kind == "fatal":
            ledger.faults_injected += 1
            raise FatalFault(site, ledger)
        if kind == "transient":
            ledger.faults_injected += 1
            if direction == "h2d":
                ledger.retry_h2d_bytes += cost_bytes
            elif direction == "d2h":
                ledger.retry_d2h_bytes += cost_bytes
            if attempt >= retry.max_retries:
                raise RetriesExhausted(site, attempt + 1)
            ledger.retries += 1
            backoff = retry.backoff_s(attempt)
            if backoff > 0.0:
                time.sleep(backoff)
            attempt += 1
            continue
        return fn(*args, **kwargs)
