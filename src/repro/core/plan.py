"""Pass plan + buffer manager: the one partition engine under every consumer.

Every counting-sort pass in the framework — the hybrid MSD driver, the LSD
baseline, ``segmented.counting_partition`` (MoE dispatch, length bucketing),
and the distributed sort's shard partitioning — needs the same plumbing:

  * digit extraction windows (``digit_at`` / ``digit_window``),
  * active-segment descriptors derived from the dense per-key bucket state
    (``active_segments`` — the JAX analogue of the paper's bucket lists),
  * block descriptor tables that chop segments AND the done gaps between
    them into KPB blocks for the constant-size fused launch (§4.2,
    ``make_region_blocks``), packed into fixed-width super-steps of B rows
    per grid step (``pack_region_blocks``) so the launch grid is
    ⌈g_max/B⌉,
  * R3 merge bookkeeping (``merge_rows``) and the positional segment/done
    updates after a pass (``apply_pass_bookkeeping``),
  * the (sub-bucket -> next-pass active segment) map that keys the fused
    next-digit histogram (§4.3, ``next_active_table``),
  * ping-pong buffer management with donation (``kernels.fused``).

This module owns all of it; ``core.hybrid``, ``core.lsd``,
``core.segmented`` and ``core.distributed`` are thin clients.  The jnp
engines (``argsort``/``scan``) share the same bookkeeping so all three
engines stay byte-identical.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.ranks import (invert_permutation, resolve_engine,
                              stable_partition_dest)
from repro.kernels import fused


class ActiveSegments(NamedTuple):
    """Dense descriptors of the active (> ∂̂) buckets, in position order."""
    base: jnp.ndarray      # (a_max,) first key of each active segment; n pad
    size: jnp.ndarray      # (a_max,) keys per active segment; 0 pad
    index: jnp.ndarray     # (n,) compact active-segment id per key
    boundary: jnp.ndarray  # (n,) bool: first key of any bucket (done or not)


class RegionBlocks(NamedTuple):
    """Block descriptor tables for one fused launch (§4.2, model M4/I4).

    One row per descriptor: active segments are partitioned, the done gaps
    between them are copied through, so one launch rewrites the whole
    ping-pong buffer.  Padding rows (beyond the pass's real block count)
    carry ``count == 0`` and scatter nothing.  Tables are either flat (G,)
    — one row per grid step — or packed (G', B) super-steps
    (``pack_region_blocks``): grid step g then loops over its B rows in
    order, which shrinks the launch grid B-fold without touching the carry
    chains (rows stay in descriptor order).
    """
    seg: jnp.ndarray     # (G,) compact active-segment id; a_max for copies/pads
    offset: jnp.ndarray  # (G,) absolute offset of the block's first key
    reset: jnp.ndarray   # (G,) 1 = first block of its region (carry reset)
    count: jnp.ndarray   # (G,) live lanes in the block
    active: jnp.ndarray  # (G,) 1 = partition block, 0 = copy-through block


def resolve_pass_engine(engine, interpret: bool) -> str:
    """Resolve an engine name with the hardware demotion rule.

    ``None``/``"auto"`` resolves per backend (``ranks.resolve_engine``), but
    an *auto-resolved* ``kernel`` only engages under interpret mode: the
    fused kernel's per-lane scatter stores are interpret-first until its
    Mosaic lowering story lands (ROADMAP open item), so compiled-hardware
    callers keep the XLA path unless they request ``engine="kernel"``
    explicitly.  One rule for every consumer — the sort drivers,
    ``single_pass_partition``, and everything above them.
    """
    auto = engine in (None, "auto")
    engine = resolve_engine(engine)
    if auto and engine == "kernel" and not interpret:
        return "argsort"
    return engine


def digit_at(ukeys: jnp.ndarray, pass_idx, k: int, d: int,
             lo: int = 0) -> jnp.ndarray:
    """MSD digit for pass ``pass_idx`` (0 = most significant); handles k % d != 0.

    ``lo`` is the static live-bit floor of the entropy-adaptive schedule:
    pass windows count down from ``k`` but never extend below ``lo`` (bits
    under the floor are globally dead and carry no ordering information).
    """
    udt = ukeys.dtype
    hi = k - pass_idx * d
    width = jnp.clip(jnp.minimum(d, hi - lo), 0, d)
    wlo = (hi - width).astype(udt)
    mask = ((jnp.array(1, udt) << width.astype(udt)) - 1).astype(udt)
    return ((ukeys >> wlo) & mask).astype(jnp.int32)


def digit_window(pass_idx, k: int, d: int, lo: int = 0) -> jnp.ndarray:
    """(6,) int32 [lo, width, next_lo, next_width, next2_lo, next2_width]
    MSD windows of a pass.

    The first pair locates this pass's digit, the second the next pass's —
    the window the fused kernel histograms during the scatter (§4.3) — and
    the third the pass after that, the *lookahead* window the adaptive
    schedule histograms alongside so an elided pass p+1 still leaves pass
    p+2's histogram in hand.  A width of 0 marks a window past the last
    pass (no fused histogram).  ``lo`` is the static live-bit floor: all
    windows clip against it, so a narrowed schedule runs ⌈(k - lo)/d⌉
    passes without touching the dead low bits.
    """
    hi = k - pass_idx * d
    width = jnp.clip(jnp.minimum(d, hi - lo), 0, d)
    wlo = hi - width
    nwidth = jnp.clip(jnp.minimum(d, wlo - lo), 0, d)
    nlo = wlo - nwidth
    n2width = jnp.clip(jnp.minimum(d, nlo - lo), 0, d)
    n2lo = nlo - n2width
    return jnp.stack([wlo, width, nlo, nwidth, n2lo, n2width]).astype(jnp.int32)


def lsd_digit_window(pass_idx: int, k: int, d: int, lo: int = 0) -> jnp.ndarray:
    """(6,) int32 LSD windows: pass p covers bits [lo + p*d, min(lo+(p+1)*d, k)).

    ``lo``/``k`` bound the live window of the adaptive schedule (static
    narrowing); the lookahead slots are 0 — the LSD driver unrolls its
    passes statically and never elides mid-sort.
    """
    wlo = lo + pass_idx * d
    width = min(d, k - wlo)
    nlo = wlo + width
    nwidth = max(0, min(d, k - nlo))
    return jnp.asarray([wlo, width, nlo, nwidth, 0, 0], jnp.int32)


def active_segments(seg_id: jnp.ndarray, done: jnp.ndarray,
                    a_max: int) -> ActiveSegments:
    """Derive the active-segment descriptors from dense per-key state."""
    n = seg_id.shape[0]
    boundary = jnp.concatenate([jnp.ones((1,), bool),
                                seg_id[1:] != seg_id[:-1]])
    astart = boundary & ~done
    asid = jnp.cumsum(astart.astype(jnp.int32)) - 1
    base = jnp.nonzero(astart, size=a_max, fill_value=n)[0].astype(jnp.int32)
    size = jnp.zeros((a_max,), jnp.int32).at[
        jnp.where(~done, asid, a_max)].add(1, mode="drop")
    return ActiveSegments(base=base, size=size, index=asid, boundary=boundary)


def max_region_blocks(n: int, kpb: int, a_max: int) -> int:
    """Static bound on fused-launch grid size (model I4 extended to gaps):
    ⌊n/KPB⌋ full blocks + one partial per active segment + one per gap."""
    return n // kpb + 2 * a_max + 2


def pack_region_blocks(blocks: RegionBlocks, batch: int,
                       seg_pad: int = None) -> RegionBlocks:
    """Pack flat descriptor rows into fixed-width (G', B) super-steps (§4.2).

    The paper over-decomposes buckets into equal blocks so thread blocks do
    equal work; the fused launch's analogue is the flat descriptor table —
    but one row per grid step pays the per-step launch machinery ``g_max``
    times.  Packing groups B *consecutive* rows per grid step instead.
    Consecutive-in-order is the compatibility rule that keeps every segment's
    carry chain intact: a region's blocks occupy consecutive rows, the kernel
    walks each super-step's rows sequentially, and the grid itself is
    sequential — so the in-segment running offset accumulates across
    super-step boundaries exactly as before.  The tail pads with inert rows
    (count 0, copy-through, carry-reset) which sit after every real row, so
    a reset there never clips a live carry; ``seg_pad`` is the tail's seg
    value — pass ``a_max`` to keep the flat table's pad convention (seg ==
    a_max marks copies/pads), as ``make_region_blocks`` does.
    """
    if batch < 1:
        raise ValueError("batch must be >= 1")
    g = blocks.seg.shape[0]
    pad = (-g) % batch
    fills = dict(seg=0 if seg_pad is None else seg_pad, offset=0, reset=1,
                 count=0, active=0)
    packed = {}
    for name, fill in fills.items():
        t = getattr(blocks, name)
        if pad:
            t = jnp.concatenate([t, jnp.full((pad,), fill, t.dtype)])
        packed[name] = t.reshape(-1, batch)
    return RegionBlocks(**packed)


def make_region_blocks(base: jnp.ndarray, size: jnp.ndarray, n: int, kpb: int,
                       g_max: int, batch: int = None) -> RegionBlocks:
    """Chop active segments and the done gaps between them into KPB blocks.

    ``base``/``size`` are (a_max,) active-segment descriptors (``n``/0 on
    padding rows).  Regions interleave gap_0, active_0, gap_1, ..., tail gap;
    every key position lands in exactly one block, so one fused launch
    rewrites the whole buffer (actives partitioned, gaps copied through).
    With ``batch`` the flat rows are additionally packed into (⌈g_max/batch⌉,
    batch) super-steps (``pack_region_blocks``) — the batched-grid form the
    fused kernel consumes.
    """
    a_max = base.shape[0]
    nreg = 2 * a_max + 1
    ends = (base + size).astype(jnp.int32)
    prev_end = jnp.concatenate([jnp.zeros((1,), jnp.int32), ends[:-1]])

    rbase = jnp.zeros((nreg,), jnp.int32)
    rbase = rbase.at[0:2 * a_max:2].set(prev_end)
    rbase = rbase.at[1:2 * a_max:2].set(base.astype(jnp.int32))
    rbase = rbase.at[2 * a_max].set(ends[-1])
    rsize = jnp.zeros((nreg,), jnp.int32)
    rsize = rsize.at[0:2 * a_max:2].set(jnp.maximum(base - prev_end, 0))
    rsize = rsize.at[1:2 * a_max:2].set(size.astype(jnp.int32))
    rsize = rsize.at[2 * a_max].set(jnp.maximum(n - ends[-1], 0))
    ract = jnp.zeros((nreg,), jnp.int32).at[1:2 * a_max:2].set(1)
    rseg = jnp.full((nreg,), a_max, jnp.int32).at[1:2 * a_max:2].set(
        jnp.arange(a_max, dtype=jnp.int32))

    # block ownership via marks + prefix sum (as the paper's M4 generation):
    # mark each non-empty region's first block, count marks up to g, map the
    # count back through the list of non-empty regions.
    nblk = (rsize + kpb - 1) // kpb
    blk_excl = jnp.cumsum(nblk) - nblk
    total = blk_excl[-1] + nblk[-1]
    marks = jnp.zeros((g_max,), jnp.int32).at[
        jnp.where(nblk > 0, blk_excl, g_max)].add(1, mode="drop")
    reg_ord = jnp.cumsum(marks) - 1
    nonempty = jnp.nonzero(nblk > 0, size=nreg, fill_value=nreg)[0]
    g = jnp.arange(g_max, dtype=jnp.int32)
    valid = g < total
    reg = jnp.clip(jnp.where(valid, nonempty[jnp.clip(reg_ord, 0, nreg - 1)],
                             nreg - 1), 0, nreg - 1)
    blk_in_reg = jnp.where(valid, g - blk_excl[reg], 0)
    offset = jnp.where(valid, rbase[reg] + blk_in_reg * kpb, 0)
    count = jnp.where(valid,
                      jnp.clip(rsize[reg] - blk_in_reg * kpb, 0, kpb), 0)
    seg = jnp.where(valid & (ract[reg] == 1), rseg[reg], a_max)
    active = jnp.where(valid, ract[reg], 0)
    reset = jnp.where(valid, (blk_in_reg == 0).astype(jnp.int32), 1)
    blocks = RegionBlocks(seg=seg.astype(jnp.int32),
                          offset=offset.astype(jnp.int32),
                          reset=reset.astype(jnp.int32),
                          count=count.astype(jnp.int32),
                          active=active.astype(jnp.int32))
    if batch is None:
        return blocks
    return pack_region_blocks(blocks, batch, seg_pad=a_max)


def merge_rows(hist: jnp.ndarray, local_threshold: int, merge_threshold: int):
    """Apply R3 to each active bucket's sub-bucket size row.

    Returns (group_start, group_done): (A, r) bools — whether sub-bucket v
    starts a new (merged) bucket, and whether that bucket is finished (<= ∂̂).
    """
    def row(s_row):
        def step(carry, s):
            acc, gid = carry
            big = s > local_threshold
            extend = (s == 0) | ((~big) & (acc + s < merge_threshold))
            ngid = jnp.where(extend, gid, gid + 1)
            nacc = jnp.where(extend, acc + s,
                             jnp.where(big, merge_threshold, s))
            return (nacc, ngid), (~extend, ~big)
        (_, _), (gstart, gdone) = lax.scan(
            step, (jnp.int32(merge_threshold), jnp.int32(0)), s_row)
        return gstart, gdone
    return jax.vmap(row)(hist)


def next_active_table(hist: jnp.ndarray, local_threshold: int,
                      a_max: int) -> jnp.ndarray:
    """(a_max * r,) map from (active segment, digit) sub-bucket to its
    compact next-pass active-segment id (``a_max`` = done next pass).

    R3 merging makes every next-pass *active* bucket a single sub-bucket
    larger than ∂̂ (merged runs are by construction <= ∂ <= ∂̂, hence done),
    so rank-among-(> ∂̂)-sub-buckets in position order IS the id the next
    pass's ``active_segments`` will assign — the invariant that lets the
    fused kernel write its §4.3 histogram straight into compact rows.
    """
    mask = (hist > local_threshold).reshape(-1)
    sid = jnp.cumsum(mask.astype(jnp.int32)) - 1
    return jnp.where(mask, sid, a_max).astype(jnp.int32)


def apply_pass_bookkeeping(seg_id, done, asegs: ActiveSegments, hist,
                           gstart, gdone, dest_base):
    """Positional segment/done updates after a counting pass.

    Works purely from the (A, r) tables — no per-key digit array — so the
    fused engine (whose digits never leave VMEM) and the jnp engines share
    it: merged-group starts (R3) become the new bucket boundaries, done
    groups are range-filled, done buckets persist in place.
    """
    n = seg_id.shape[0]
    nb = jnp.zeros((n,), bool)
    keep = asegs.boundary & done                  # done buckets persist
    nb = nb.at[jnp.where(keep, jnp.arange(n), n)].set(True, mode="drop")
    nb = nb.at[jnp.where(gstart.reshape(-1), dest_base.reshape(-1), n)].set(
        True, mode="drop")
    nb = nb.at[0].set(True)
    new_seg = jnp.cumsum(nb.astype(jnp.int32)) - 1

    # done ranges via +1/-1 marks and a prefix sum (empty groups cancel)
    gd = (gdone & (hist > 0)).reshape(-1)
    db = dest_base.reshape(-1)
    de = db + hist.reshape(-1)
    dm = jnp.zeros((n + 1,), jnp.int32)
    dm = dm.at[jnp.where(gd, db, n)].add(1, mode="drop")
    dm = dm.at[jnp.where(gd, de, n)].add(-1, mode="drop")
    new_done = done | (jnp.cumsum(dm)[:n] > 0)
    return new_seg, new_done


def single_pass_partition(ids: jnp.ndarray, num_buckets: int,
                          engine: str = None, interpret: bool = None,
                          kpb: int = 1024, step_batch: int = 8):
    """One engine-selected stable counting pass over flat bucket ids.

    The primitive under ``segmented.counting_partition`` (MoE dispatch,
    length bucketing, shard partitioning): returns ``(dest, perm, counts)``.
    ``engine="kernel"`` runs ONE fused Pallas launch (plus the prologue
    histogram); the jnp engines use ``ranks.stable_partition_dest``.

    Auto-resolved engines obey the ``resolve_pass_engine`` hardware demotion
    rule (fused kernel under interpret only, until its Mosaic lowering
    lands); ``engine="kernel"`` explicitly is always honoured.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    engine = resolve_pass_engine(engine, interpret)
    m = ids.shape[0]
    ids = ids.astype(jnp.int32)
    if m == 0 or engine != "kernel":
        jnp_engine = engine if engine != "kernel" else "argsort"
        dest = stable_partition_dest(ids, num_buckets, engine=jnp_engine)
        perm = invert_permutation(dest)
        counts = jnp.bincount(ids, length=num_buckets).astype(jnp.int32)
        return dest, perm, counts

    width = max(1, (num_buckets - 1).bit_length())
    r = 1 << width
    kpb = max(8, min(kpb, 1 << (m - 1).bit_length()))   # one block if m small
    iota = jnp.arange(m, dtype=jnp.int32)
    (ck, cv), (ak, av) = fused.make_ping_pong(ids, (iota,), kpb)
    hist0 = fused.initial_histogram(ck, m, 0, width, r, 1, kpb,
                                    interpret=interpret)
    base_excl = jnp.cumsum(hist0, axis=1) - hist0            # base 0
    blocks = make_region_blocks(jnp.zeros((1,), jnp.int32),
                                jnp.full((1,), m, jnp.int32), m, kpb,
                                max_region_blocks(m, kpb, 1),
                                batch=step_batch)
    sc = jnp.asarray([0, width, 0, 0, 0, 0], jnp.int32)
    nsid = jnp.zeros((r,), jnp.int32)
    _, (perm_pad,), _ = fused.fused_counting_pass(
        ck, cv, ak, av, sc, *blocks, base_excl, nsid,
        kpb=kpb, r=r, a_max=1, n=m, interpret=interpret)
    perm = perm_pad[:m]
    dest = invert_permutation(perm)
    return dest, perm, hist0[0, :num_buckets]


# --- contract declaration (verified by repro.analysis; see analysis/contracts)
# One standalone partition = prologue histogram + ONE fused launch; the iota
# permutation payload rides as one value leaf (vals = 1), so the pass moves
# (2·1+1) key sweeps + 2 payload sweeps over the padded buffer.
ANALYSIS_CONTRACT = {
    "entry": "repro.core.plan.single_pass_partition",
    "census": {
        "launch_total": "2",
        "while_body_launches": "[]",
        "fused_grid": "ceil_div(g_max, B)",
    },
    "sort_free": True,
    "donation": {"_fused_pass_kernel": "1 + vals"},
    "transfer": {
        "sweep_kernels": ["_hist_kernel", "_fused_pass_kernel"],
        "bytes": "(2 * passes + 1) * n_pad * kb + 2 * passes * n_pad * vb",
    },
}
