"""The paper's analytical model (§4.5): bucket/block bounds and memory budget.

In the CUDA original the model proves the feasibility of tracking millions of
buckets.  In this JAX port the model is *load-bearing*: XLA requires static
shapes, so the model's upper bounds become the static sizes of every
bookkeeping array.  I1–I4 and M1–M5 below use the paper's notation.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SortConfig:
    """Tuning parameters of the hybrid radix sort (paper Table 1/Table 3)."""
    d: int = 8                 # bits per digit
    kpb: int = 3456            # keys per block (tile), per Table 3
    local_threshold: int = 4224   # ∂̂ — buckets <= this are locally sorted
    merge_threshold: int = 3000   # ∂ — merge runs of sub-buckets below this
    rank_engine: str = "auto"  # pass engine default (see core.ranks.resolve_engine)
    step_batch: int = 8        # descriptor rows per fused-launch grid step
                               # (plan.pack_region_blocks super-step width)
    adaptive: bool = True      # entropy-adaptive schedule: narrow the digit
                               # window to the live bits of concrete inputs
                               # and elide single-digit passes mid-sort

    def __post_init__(self):
        if not (0 < self.d <= 16):
            raise ValueError("d must be in (0, 16]")
        if self.merge_threshold > self.local_threshold:
            raise ValueError("requires ∂ <= ∂̂ (R3)")
        if self.step_batch < 1:
            raise ValueError("step_batch must be >= 1")

    @property
    def radix(self) -> int:
        return 1 << self.d


# Paper Table 3 defaults, keyed by (key_bytes, value_bytes or 0).
PAPER_TABLE3 = {
    (4, 0): SortConfig(d=8, kpb=6912, local_threshold=9216, merge_threshold=3000),
    (8, 0): SortConfig(d=8, kpb=3456, local_threshold=4224, merge_threshold=3000),
    (4, 4): SortConfig(d=8, kpb=3456, local_threshold=5760, merge_threshold=3000),
    (8, 8): SortConfig(d=8, kpb=2304, local_threshold=3840, merge_threshold=3000),
}


def default_config(key_bytes: int, value_bytes: int = 0) -> SortConfig:
    return PAPER_TABLE3.get((key_bytes, value_bytes),
                            PAPER_TABLE3[(8, 8)] if value_bytes else PAPER_TABLE3[(8, 0)])


def num_digits(key_bits: int, d: int) -> int:
    return math.ceil(key_bits / d)


# ----- the bounds (I1..I4) -------------------------------------------------

def max_active_buckets(n: int, cfg: SortConfig) -> int:
    """I1: at most ⌊n/∂̂⌋ buckets exceed the local-sort threshold."""
    return max(1, n // (cfg.local_threshold + 1) + 1)


def max_total_buckets(n: int, cfg: SortConfig) -> int:
    """I3: min(⌊2n/∂⌋ + ⌊n/∂̂⌋, r·⌊n/∂̂⌋), plus one radix worth of slack."""
    i2 = cfg.radix * max(1, n // cfg.local_threshold)
    i3 = 2 * n // cfg.merge_threshold + n // cfg.local_threshold
    return min(i2, i3) + cfg.radix


def max_blocks(n: int, cfg: SortConfig) -> int:
    """I4: ⌊n/KPB⌋ + ⌊n/∂̂⌋ blocks (full blocks + one remainder per bucket)."""
    return n // cfg.kpb + max_active_buckets(n, cfg) + 1


# ----- the memory budget (M1..M5), in bytes --------------------------------

def memory_budget(n: int, key_bits: int, cfg: SortConfig) -> dict:
    r = cfg.radix
    a = max_active_buckets(n, cfg)
    blocks = max_blocks(n, cfg)
    m1 = 2 * n * key_bits // 8
    m2 = 4 * r * a
    m3 = 4 * r * blocks
    m4 = 2 * 16 * blocks
    m5 = 12 * max_total_buckets(n, cfg)
    aux = m2 + m3 + m4 + m5
    return {
        "M1_input_and_aux": m1,
        "M2_bucket_histograms": m2,
        "M3_block_histograms": m3,
        "M4_block_assignments": m4,
        "M5_local_sort_assignments": m5,
        "aux_total": aux,
        "aux_over_m1": aux / max(m1, 1),
    }


# ----- memory-traffic model (the paper's headline argument) ----------------

def pass_counts(key_bits: int, d_hybrid: int = 8, d_lsd: int = 5) -> dict:
    """Worst-case counting passes: hybrid ⌈k/8⌉ vs LSD ⌈k/5⌉ (CUB)."""
    return {"hybrid": num_digits(key_bits, d_hybrid),
            "lsd": num_digits(key_bits, d_lsd)}


def traffic_bytes(n: int, key_bytes: int, value_bytes: int, passes: int,
                  reads_per_pass: int = 2, writes_per_pass: int = 1) -> int:
    """Device-memory traffic of a radix sort: each pass reads the keys twice
    (histogram + scatter) and writes once; values are read+written once per
    pass (scatter only)."""
    key_traffic = n * key_bytes * (reads_per_pass + writes_per_pass) * passes
    val_traffic = n * value_bytes * 2 * passes
    return key_traffic + val_traffic


def expected_speedup(key_bits: int, value_bytes: int = 0,
                     d_hybrid: int = 8, d_lsd: int = 5) -> float:
    """The paper's anticipated speedup from traffic reduction alone
    (e.g. 64-bit keys: 13 vs 8 passes -> 1.625x; 32-bit: 7 vs 4 -> 1.75x)."""
    kb = key_bits // 8
    n = 1  # ratio — n cancels
    h = traffic_bytes(n, kb, value_bytes, num_digits(key_bits, d_hybrid))
    l = traffic_bytes(n, kb, value_bytes, num_digits(key_bits, d_lsd))
    return l / h
