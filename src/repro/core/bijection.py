"""Order-preserving bijections from primitive key types to unsigned bit-strings.

Paper §4.6: radix sorting operates on unsigned integers; signed ints and IEEE
floats are mapped to an order-preserving unsigned representation before the
first counting-sort pass and mapped back during the local sort / last pass
(Herf, "Radix tricks", 2001).

  * unsigned ints: identity
  * signed ints:   flip the sign bit
  * floats:        if sign bit set -> flip ALL bits, else -> flip sign bit only

Float special values (pinned by the bijection test wall — IEEE-754
totalOrder semantics, which every float key inherits through ``hybrid_sort``
and ``oocsort``):

  * the map is a **bijection on bit patterns**: every value — NaNs with any
    payload included — round-trips bit-exactly through
    ``from_ordered_bits(to_ordered_bits(x))``;
  * ``-0.0 < +0.0``: the two zeros encode to adjacent but distinct bit
    patterns (``-0.0`` just below ``+0.0``), so both survive a sort
    unchanged and all negative values sort strictly below both;
  * NaNs sort to **deterministic extremes by sign bit**: negative-signed
    NaNs below ``-inf``, positive-signed NaNs above ``+inf``, ordered among
    themselves by payload — a NaN key can never land between finite keys or
    be silently canonicalised.

All functions are jit-safe and shape-preserving.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# dtype -> (unsigned carrier dtype, key bit width)
_CARRIER = {
    jnp.dtype(jnp.uint8): (jnp.uint8, 8),
    jnp.dtype(jnp.uint16): (jnp.uint16, 16),
    jnp.dtype(jnp.uint32): (jnp.uint32, 32),
    jnp.dtype(jnp.uint64): (jnp.uint64, 64),
    jnp.dtype(jnp.int8): (jnp.uint8, 8),
    jnp.dtype(jnp.int16): (jnp.uint16, 16),
    jnp.dtype(jnp.int32): (jnp.uint32, 32),
    jnp.dtype(jnp.int64): (jnp.uint64, 64),
    jnp.dtype(jnp.float32): (jnp.uint32, 32),
    jnp.dtype(jnp.float64): (jnp.uint64, 64),
    jnp.dtype(jnp.bfloat16): (jnp.uint16, 16),
    jnp.dtype(jnp.float16): (jnp.uint16, 16),
}


def key_bits(dtype) -> int:
    """Number of key bits k for a supported key dtype."""
    return _CARRIER[jnp.dtype(dtype)][1]


def carrier_dtype(dtype):
    """Unsigned dtype the radix sort runs on for a given key dtype."""
    return _CARRIER[jnp.dtype(dtype)][0]


def _sign_mask(udtype):
    bits = key_bits(udtype)
    return jnp.array(1, dtype=udtype) << (bits - 1)


def to_ordered_bits(keys: jnp.ndarray) -> jnp.ndarray:
    """Map keys of any supported dtype to order-preserving unsigned bits."""
    dt = jnp.dtype(keys.dtype)
    if dt not in _CARRIER:
        raise TypeError(f"unsupported key dtype {dt}")
    udtype, _ = _CARRIER[dt]
    if jnp.issubdtype(dt, jnp.unsignedinteger):
        return keys.astype(udtype)
    bits = keys.view(udtype) if not jnp.issubdtype(dt, jnp.unsignedinteger) else keys
    sign = _sign_mask(udtype)
    if jnp.issubdtype(dt, jnp.signedinteger):
        return bits ^ sign
    # floating point: two's-complement-style total order (NaNs land at extremes)
    neg = (bits & sign) != 0
    return jnp.where(neg, ~bits, bits ^ sign)


def from_ordered_bits(ubits: jnp.ndarray, dtype) -> jnp.ndarray:
    """Inverse of :func:`to_ordered_bits`."""
    dt = jnp.dtype(dtype)
    udtype, _ = _CARRIER[dt]
    ubits = ubits.astype(udtype)
    if jnp.issubdtype(dt, jnp.unsignedinteger):
        return ubits.astype(dt)
    sign = _sign_mask(udtype)
    if jnp.issubdtype(dt, jnp.signedinteger):
        return (ubits ^ sign).view(dt)
    was_neg = (ubits & sign) == 0  # encoded negatives have sign bit cleared
    bits = jnp.where(was_neg, ~ubits, ubits ^ sign)
    return bits.view(dt)


def to_ordered_bits_np(keys: np.ndarray) -> np.ndarray:
    """NumPy mirror of :func:`to_ordered_bits` for host-resident keys.

    Bit-for-bit the same map as the jit version (the bijection parity test
    pins this), so host-side tooling — checksum verification of spilled
    runs, reference orderings in tests — can encode keys without a device
    round-trip.
    """
    dt = np.dtype(keys.dtype)
    if jnp.dtype(dt) not in _CARRIER:
        raise TypeError(f"unsupported key dtype {dt}")
    udt = np.dtype(carrier_dtype(dt))
    keys = np.asarray(keys)
    if np.issubdtype(dt, np.unsignedinteger):
        return keys.astype(udt, copy=False)
    bits = keys.view(udt)
    sign = udt.type(1 << (np.iinfo(udt).bits - 1))
    if np.issubdtype(dt, np.signedinteger):
        return bits ^ sign
    neg = (bits & sign) != 0
    return np.where(neg, ~bits, bits ^ sign)


def from_ordered_bits_np(ubits: np.ndarray, dtype) -> np.ndarray:
    """NumPy mirror of :func:`from_ordered_bits` for host-resident bits.

    The out-of-core spill path keeps merged runs host-side, so the final
    unsigned-bits -> key-dtype inverse must not round-trip N bytes through
    the device just to flip sign bits; this is the same bijection on numpy.
    """
    dt = np.dtype(dtype)
    udt = np.dtype(carrier_dtype(dtype))
    ubits = np.asarray(ubits).astype(udt, copy=False)
    if np.issubdtype(dt, np.unsignedinteger):
        return ubits.astype(dt, copy=False)
    sign = udt.type(1 << (np.iinfo(udt).bits - 1))
    if np.issubdtype(dt, np.signedinteger):
        return (ubits ^ sign).view(dt)
    was_neg = (ubits & sign) == 0  # encoded negatives have sign bit cleared
    bits = np.where(was_neg, ~ubits, ubits ^ sign)
    return bits.view(dt)
