"""Order-preserving bijections from primitive key types to unsigned bit-strings.

Paper §4.6: radix sorting operates on unsigned integers; signed ints and IEEE
floats are mapped to an order-preserving unsigned representation before the
first counting-sort pass and mapped back during the local sort / last pass
(Herf, "Radix tricks", 2001).

  * unsigned ints: identity
  * signed ints:   flip the sign bit
  * floats:        if sign bit set -> flip ALL bits, else -> flip sign bit only

Float special values (pinned by the bijection test wall — IEEE-754
totalOrder semantics, which every float key inherits through ``hybrid_sort``
and ``oocsort``):

  * the map is a **bijection on bit patterns**: every value — NaNs with any
    payload included — round-trips bit-exactly through
    ``from_ordered_bits(to_ordered_bits(x))``;
  * ``-0.0 < +0.0``: the two zeros encode to adjacent but distinct bit
    patterns (``-0.0`` just below ``+0.0``), so both survive a sort
    unchanged and all negative values sort strictly below both;
  * NaNs sort to **deterministic extremes by sign bit**: negative-signed
    NaNs below ``-inf``, positive-signed NaNs above ``+inf``, ordered among
    themselves by payload — a NaN key can never land between finite keys or
    be silently canonicalised.

All functions are jit-safe and shape-preserving.

Compressed-key mode (entropy-adaptive sorting): ``CompressionPlan`` bit-packs
the *live* columns of the ordered-bits domain — the bit positions where keys
actually differ — into a contiguous low window, dropping globally-constant
columns entirely.  Two ordered values differ first at some live bit (their
dead bits are equal by construction), and packing preserves the relative
significance order of live bits, so the packed keys sort in exactly the same
order as the originals; ``unpack_ordered_bits`` then restores the dead
columns bit-exactly.  Plans are built from host-resident key summaries
(Python-int masks), so packing lowers to a statically unrolled shift/mask
chain under jit and has a NumPy mirror for host-resident (out-of-core
spilled) runs.  A uint64 key set with <= 32 live bits packs into a uint32
carrier — half the sort traffic and no x64 requirement on the device path
that only ever sees the packed representation.
"""
from __future__ import annotations

from typing import List, NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

# dtype -> (unsigned carrier dtype, key bit width)
_CARRIER = {
    jnp.dtype(jnp.uint8): (jnp.uint8, 8),
    jnp.dtype(jnp.uint16): (jnp.uint16, 16),
    jnp.dtype(jnp.uint32): (jnp.uint32, 32),
    jnp.dtype(jnp.uint64): (jnp.uint64, 64),
    jnp.dtype(jnp.int8): (jnp.uint8, 8),
    jnp.dtype(jnp.int16): (jnp.uint16, 16),
    jnp.dtype(jnp.int32): (jnp.uint32, 32),
    jnp.dtype(jnp.int64): (jnp.uint64, 64),
    jnp.dtype(jnp.float32): (jnp.uint32, 32),
    jnp.dtype(jnp.float64): (jnp.uint64, 64),
    jnp.dtype(jnp.bfloat16): (jnp.uint16, 16),
    jnp.dtype(jnp.float16): (jnp.uint16, 16),
}


def key_bits(dtype) -> int:
    """Number of key bits k for a supported key dtype."""
    return _CARRIER[jnp.dtype(dtype)][1]


def carrier_dtype(dtype):
    """Unsigned dtype the radix sort runs on for a given key dtype."""
    return _CARRIER[jnp.dtype(dtype)][0]


def _sign_mask(udtype):
    bits = key_bits(udtype)
    return jnp.array(1, dtype=udtype) << (bits - 1)


def to_ordered_bits(keys: jnp.ndarray) -> jnp.ndarray:
    """Map keys of any supported dtype to order-preserving unsigned bits."""
    dt = jnp.dtype(keys.dtype)
    if dt not in _CARRIER:
        raise TypeError(f"unsupported key dtype {dt}")
    udtype, _ = _CARRIER[dt]
    if jnp.issubdtype(dt, jnp.unsignedinteger):
        return keys.astype(udtype)
    bits = keys.view(udtype) if not jnp.issubdtype(dt, jnp.unsignedinteger) else keys
    sign = _sign_mask(udtype)
    if jnp.issubdtype(dt, jnp.signedinteger):
        return bits ^ sign
    # floating point: two's-complement-style total order (NaNs land at extremes)
    neg = (bits & sign) != 0
    return jnp.where(neg, ~bits, bits ^ sign)


def from_ordered_bits(ubits: jnp.ndarray, dtype) -> jnp.ndarray:
    """Inverse of :func:`to_ordered_bits`."""
    dt = jnp.dtype(dtype)
    udtype, _ = _CARRIER[dt]
    ubits = ubits.astype(udtype)
    if jnp.issubdtype(dt, jnp.unsignedinteger):
        return ubits.astype(dt)
    sign = _sign_mask(udtype)
    if jnp.issubdtype(dt, jnp.signedinteger):
        return (ubits ^ sign).view(dt)
    was_neg = (ubits & sign) == 0  # encoded negatives have sign bit cleared
    bits = jnp.where(was_neg, ~ubits, ubits ^ sign)
    return bits.view(dt)


def to_ordered_bits_np(keys: np.ndarray) -> np.ndarray:
    """NumPy mirror of :func:`to_ordered_bits` for host-resident keys.

    Bit-for-bit the same map as the jit version (the bijection parity test
    pins this), so host-side tooling — checksum verification of spilled
    runs, reference orderings in tests — can encode keys without a device
    round-trip.
    """
    dt = np.dtype(keys.dtype)
    if jnp.dtype(dt) not in _CARRIER:
        raise TypeError(f"unsupported key dtype {dt}")
    udt = np.dtype(carrier_dtype(dt))
    keys = np.asarray(keys)
    if np.issubdtype(dt, np.unsignedinteger):
        return keys.astype(udt, copy=False)
    bits = keys.view(udt)
    sign = udt.type(1 << (np.iinfo(udt).bits - 1))
    if np.issubdtype(dt, np.signedinteger):
        return bits ^ sign
    neg = (bits & sign) != 0
    return np.where(neg, ~bits, bits ^ sign)


def from_ordered_bits_np(ubits: np.ndarray, dtype) -> np.ndarray:
    """NumPy mirror of :func:`from_ordered_bits` for host-resident bits.

    The out-of-core spill path keeps merged runs host-side, so the final
    unsigned-bits -> key-dtype inverse must not round-trip N bytes through
    the device just to flip sign bits; this is the same bijection on numpy.
    """
    dt = np.dtype(dtype)
    udt = np.dtype(carrier_dtype(dtype))
    ubits = np.asarray(ubits).astype(udt, copy=False)
    if np.issubdtype(dt, np.unsignedinteger):
        return ubits.astype(dt, copy=False)
    sign = udt.type(1 << (np.iinfo(udt).bits - 1))
    if np.issubdtype(dt, np.signedinteger):
        return (ubits ^ sign).view(dt)
    was_neg = (ubits & sign) == 0  # encoded negatives have sign bit cleared
    bits = np.where(was_neg, ~ubits, ubits ^ sign)
    return bits.view(dt)


# ---------------------------------------------------------------------------
# Compressed keys: pack out globally-dead bit columns (entropy adaptation)
# ---------------------------------------------------------------------------

_WIDTH_TO_UNSIGNED = {8: np.uint8, 16: np.uint16, 32: np.uint32, 64: np.uint64}


class CompressionPlan(NamedTuple):
    """Static bit-packing plan over the ordered-bits carrier domain.

    ``mask`` marks the live columns (bit positions where at least two keys
    differ), ``dead`` holds the constant value every key shares on the
    remaining columns, and ``source_bits`` is the carrier width both are
    defined over.  All three are Python ints, so a plan is a static jit
    argument and the pack/unpack shift chains unroll at trace time.
    """

    mask: int
    dead: int
    source_bits: int

    @property
    def packed_bits(self) -> int:
        """Live-bit count; at least 1 so an all-equal key set still packs
        into a real (all-zero) carrier instead of a zero-width one."""
        return max(1, bin(self.mask).count("1"))

    def runs(self) -> List[Tuple[int, int, int]]:
        """Contiguous live-bit runs as ``(src_lo, width, dst_lo)`` triples,
        least-significant first — one shift/mask/or per run to pack."""
        out: List[Tuple[int, int, int]] = []
        m, bit, dst = self.mask, 0, 0
        while m >> bit:
            while not (m >> bit) & 1:
                bit += 1
            lo = bit
            while bit < self.source_bits and (m >> bit) & 1:
                bit += 1
            out.append((lo, bit - lo, dst))
            dst += bit - lo
        return out


def packed_carrier_dtype(plan: CompressionPlan) -> np.dtype:
    """Smallest unsigned dtype that holds the packed live bits."""
    for width, dt in sorted(_WIDTH_TO_UNSIGNED.items()):
        if plan.packed_bits <= width:
            return np.dtype(dt)
    raise ValueError(f"packed width {plan.packed_bits} exceeds 64 bits")


def source_carrier_dtype(plan: CompressionPlan) -> np.dtype:
    """Unsigned dtype of the (uncompressed) ordered-bits domain."""
    return np.dtype(_WIDTH_TO_UNSIGNED[plan.source_bits])


def compression_plan_np(ubits: np.ndarray) -> CompressionPlan:
    """Build a plan from a host-resident ordered-bits array.

    One OR-reduce and one AND-reduce over the keys: live = OR ^ AND (the
    columns where the reduces disagree), dead value = the shared AND bits
    outside the live mask.  An empty key set gets the identity plan (full
    mask) — nothing is known about the columns, so nothing is dropped.
    """
    ubits = np.asarray(ubits)
    bits = np.iinfo(ubits.dtype).bits
    if ubits.size == 0:
        return CompressionPlan(mask=(1 << bits) - 1, dead=0, source_bits=bits)
    flat = ubits.reshape(-1)
    orv = int(np.bitwise_or.reduce(flat))
    andv = int(np.bitwise_and.reduce(flat))
    mask = orv ^ andv
    return CompressionPlan(mask=mask, dead=andv & ~mask, source_bits=bits)


def pack_ordered_bits(ubits: jnp.ndarray, plan: CompressionPlan) -> jnp.ndarray:
    """Drop the dead columns: gather the live runs into a contiguous low
    window and narrow to the smallest carrier that holds them."""
    src = np.dtype(ubits.dtype)
    acc = jnp.zeros(ubits.shape, dtype=src)
    for lo, width, dst in plan.runs():
        m = src.type(((1 << width) - 1) & ((1 << plan.source_bits) - 1))
        acc = acc | (((ubits >> lo) & m) << dst)
    return acc.astype(packed_carrier_dtype(plan))


def unpack_ordered_bits(packed: jnp.ndarray, plan: CompressionPlan) -> jnp.ndarray:
    """Exact inverse of :func:`pack_ordered_bits`: widen back to the source
    carrier, scatter the live runs home, and restore the dead-bit constant."""
    src = source_carrier_dtype(plan)
    x = packed.astype(src)
    acc = jnp.full(packed.shape, src.type(plan.dead), dtype=src)
    for lo, width, dst in plan.runs():
        m = src.type(((1 << width) - 1) & ((1 << plan.source_bits) - 1))
        acc = acc | (((x >> dst) & m) << lo)
    return acc


def pack_ordered_bits_np(ubits: np.ndarray, plan: CompressionPlan) -> np.ndarray:
    """NumPy mirror of :func:`pack_ordered_bits` (out-of-core host chunks)."""
    src = np.dtype(ubits.dtype)
    acc = np.zeros(ubits.shape, dtype=src)
    for lo, width, dst in plan.runs():
        m = src.type(((1 << width) - 1) & ((1 << plan.source_bits) - 1))
        acc |= ((ubits >> src.type(lo)) & m) << src.type(dst)
    return acc.astype(packed_carrier_dtype(plan), copy=False)


def unpack_ordered_bits_np(packed: np.ndarray, plan: CompressionPlan) -> np.ndarray:
    """NumPy mirror of :func:`unpack_ordered_bits`."""
    src = source_carrier_dtype(plan)
    x = np.asarray(packed).astype(src, copy=False)
    acc = np.full(x.shape, src.type(plan.dead), dtype=src)
    for lo, width, dst in plan.runs():
        m = src.type(((1 << width) - 1) & ((1 << plan.source_bits) - 1))
        acc |= ((x >> src.type(dst)) & m) << src.type(lo)
    return acc
