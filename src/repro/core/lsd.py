"""LSD radix sort baseline — the CUB/Merrill analogue (paper §3).

State-of-the-art GPU radix sorts are least-significant-digit-first with d = 4
or 5 bits per *stable* pass (CUB 1.5.1: d=5; CUB 1.6.4 appendix: up to d=7).
This module is the measured baseline the hybrid sort is compared against: the
pass structure (⌈k/d⌉ stable counting passes) is what produces the paper's
1.6–1.75x traffic ratio.

``lsd_sort`` routes through the same engine selector as ``hybrid_sort``:
``argsort``/``scan`` compute each pass's permutation in jnp; ``kernel`` runs
ONE fused Pallas launch per pass (``kernels.fused``) over donated ping-pong
buffers, with each pass's digit histogram fused out of the previous pass's
scatter (§4.3) — so the kernel engine reads the keys once and writes them
once per pass, plus a single prologue histogram sweep for pass 0.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bijection, model, plan
from repro.core.ranks import stable_partition_dest
from repro.kernels import fused


@functools.partial(jax.jit, static_argnames=("d", "k", "engine", "kpb",
                                             "step_batch", "interpret"))
def _lsd_sort_bits(ukeys, vals, d: int, k: int, engine: str, kpb: int,
                   step_batch: int, interpret: bool):
    nd = model.num_digits(k, d)
    udt = ukeys.dtype
    n = ukeys.shape[0]

    if engine == "kernel":
        # LSD is the degenerate plan: one always-active segment covering
        # [0, n), no merging, ⌈k/d⌉ statically unrolled fused launches.
        r = 1 << d
        leaves, treedef = jax.tree.flatten(vals)
        (ck, cv), (ak, av) = fused.make_ping_pong(ukeys, leaves, kpb)
        base = jnp.zeros((1,), jnp.int32)
        size = jnp.full((1,), n, jnp.int32)
        blocks = plan.make_region_blocks(base, size, n, kpb,
                                         plan.max_region_blocks(n, kpb, 1),
                                         batch=step_batch)
        nsid = jnp.zeros((r,), jnp.int32)     # every sub-bucket -> segment 0
        w0 = min(d, k)
        seg_hist = fused.initial_histogram(ck, n, 0, w0, r, 1, kpb,
                                           interpret=interpret)
        for p in range(nd):
            base_excl = jnp.cumsum(seg_hist, axis=1) - seg_hist
            sc = plan.lsd_digit_window(p, k, d)
            nk, nv, hist_next = fused.fused_counting_pass(
                ck, cv, ak, av, sc, *blocks, base_excl, nsid,
                kpb=kpb, r=r, a_max=1, n=n, interpret=interpret)
            # flip: written buffers become current, old ones donate next
            ak, av, ck, cv = ck, cv, nk, nv
            seg_hist = hist_next.reshape(1, r)
        return ck[:n], jax.tree.unflatten(treedef, [v[:n] for v in cv])

    def body(p, state):
        ukeys, vals = state
        shift = jnp.array(p * d, udt)
        # handle partial top digit: pass p covers bits [p*d, min((p+1)*d, k))
        width = jnp.minimum(d, k - p * d).astype(udt)
        mask = ((jnp.array(1, udt) << width) - 1).astype(udt)
        digit = ((ukeys >> shift) & mask).astype(jnp.int32)
        dest = stable_partition_dest(digit, 1 << d, engine=engine)
        ukeys = jnp.zeros_like(ukeys).at[dest].set(ukeys)
        vals = jax.tree.map(lambda v: jnp.zeros_like(v).at[dest].set(v), vals)
        return ukeys, vals

    ukeys, vals = lax.fori_loop(0, nd, body, (ukeys, vals))
    return ukeys, vals


def lsd_sort(keys: jnp.ndarray, values: Any = None, d: int = 5,
             engine: Optional[str] = None, kpb: int = 1024,
             step_batch: int = 8, interpret: Optional[bool] = None):
    """Stable LSD radix sort with ``d``-bit digits (default 5 — the CUB proxy).

    ``engine`` is resolved like ``hybrid_sort``'s (``argsort``/``scan``/
    ``kernel``/``auto``); ``kpb`` is the kernel engine's keys-per-block and
    ``step_batch`` its descriptor rows per fused-launch grid step
    (``plan.pack_region_blocks``).
    """
    if keys.ndim != 1:
        raise ValueError("lsd_sort expects a 1-D key array")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # auto-resolved "kernel" engages under interpret mode only (core.plan)
    engine = plan.resolve_pass_engine(engine, interpret)
    k = bijection.key_bits(keys.dtype)
    if keys.shape[0] == 0:
        return keys if values is None else (keys, values)
    ukeys = bijection.to_ordered_bits(keys)
    vals = values if values is not None else ()
    ukeys, vals = _lsd_sort_bits(ukeys, vals, d, k, engine, kpb, step_batch,
                                 interpret)
    out = bijection.from_ordered_bits(ukeys, keys.dtype)
    return out if values is None else (out, vals)
