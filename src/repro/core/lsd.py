"""LSD radix sort baseline — the CUB/Merrill analogue (paper §3).

State-of-the-art GPU radix sorts are least-significant-digit-first with d = 4
or 5 bits per *stable* pass (CUB 1.5.1: d=5; CUB 1.6.4 appendix: up to d=7).
This module is the measured baseline the hybrid sort is compared against: the
pass structure (⌈k/d⌉ stable counting passes) is what produces the paper's
1.6–1.75x traffic ratio.

``lsd_sort`` routes through the same engine selector as ``hybrid_sort``:
``argsort``/``scan`` compute each pass's permutation in jnp; ``kernel`` runs
ONE fused Pallas launch per pass (``kernels.fused``) over donated ping-pong
buffers, with each pass's digit histogram fused out of the previous pass's
scatter (§4.3) — so the kernel engine reads the keys once and writes them
once per pass, plus a single prologue histogram sweep for pass 0.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bijection, hybrid, model, plan
from repro.core.ranks import stable_partition_dest
from repro.kernels import fused


@functools.partial(jax.jit, static_argnames=("d", "k", "engine", "kpb",
                                             "step_batch", "interpret", "lo"))
def _lsd_sort_bits(ukeys, vals, d: int, k: int, engine: str, kpb: int,
                   step_batch: int, interpret: bool, lo: int = 0):
    # [lo, k) is the live-bit window of the entropy-adaptive schedule: bits
    # outside it are globally constant, and a stable pass over a constant
    # digit is the identity permutation — eliding it changes nothing, not
    # even the permutation of equal keys.
    nd = model.num_digits(max(k - lo, 0), d)
    udt = ukeys.dtype
    n = ukeys.shape[0]

    if engine == "kernel":
        # LSD is the degenerate plan: one always-active segment covering
        # [0, n), no merging, ⌈k/d⌉ statically unrolled fused launches.
        r = 1 << d
        leaves, treedef = jax.tree.flatten(vals)
        (ck, cv), (ak, av) = fused.make_ping_pong(ukeys, leaves, kpb)
        base = jnp.zeros((1,), jnp.int32)
        size = jnp.full((1,), n, jnp.int32)
        blocks = plan.make_region_blocks(base, size, n, kpb,
                                         plan.max_region_blocks(n, kpb, 1),
                                         batch=step_batch)
        nsid = jnp.zeros((r,), jnp.int32)     # every sub-bucket -> segment 0
        w0 = min(d, max(k - lo, 1))
        seg_hist = fused.initial_histogram(ck, n, lo, w0, r, 1, kpb,
                                           interpret=interpret)
        for p in range(nd):
            base_excl = jnp.cumsum(seg_hist, axis=1) - seg_hist
            sc = plan.lsd_digit_window(p, k, d, lo=lo)
            nk, nv, hist_next = fused.fused_counting_pass(
                ck, cv, ak, av, sc, *blocks, base_excl, nsid,
                kpb=kpb, r=r, a_max=1, n=n, interpret=interpret)
            # flip: written buffers become current, old ones donate next
            ak, av, ck, cv = ck, cv, nk, nv
            seg_hist = hist_next.reshape(1, r)
        return ck[:n], jax.tree.unflatten(treedef, [v[:n] for v in cv])

    def body(p, state):
        ukeys, vals = state
        shift = jnp.asarray(lo + p * d).astype(udt)
        # partial top digit: pass p covers bits [lo+p*d, min(lo+(p+1)*d, k))
        width = jnp.minimum(d, k - lo - p * d).astype(udt)
        mask = ((jnp.array(1, udt) << width) - 1).astype(udt)
        digit = ((ukeys >> shift) & mask).astype(jnp.int32)
        dest = stable_partition_dest(digit, 1 << d, engine=engine)
        ukeys = jnp.zeros_like(ukeys).at[dest].set(ukeys)
        vals = jax.tree.map(lambda v: jnp.zeros_like(v).at[dest].set(v), vals)
        return ukeys, vals

    ukeys, vals = lax.fori_loop(0, nd, body, (ukeys, vals))
    return ukeys, vals


def lsd_sort(keys: jnp.ndarray, values: Any = None, d: int = 5,
             engine: Optional[str] = None, kpb: int = 1024,
             step_batch: int = 8, interpret: Optional[bool] = None,
             adaptive: bool = True, return_passes: bool = False):
    """Stable LSD radix sort with ``d``-bit digits (default 5 — the CUB proxy).

    ``engine`` is resolved like ``hybrid_sort``'s (``argsort``/``scan``/
    ``kernel``/``auto``); ``kpb`` is the kernel engine's keys-per-block and
    ``step_batch`` its descriptor rows per fused-launch grid step
    (``plan.pack_region_blocks``).

    ``adaptive`` narrows the pass schedule to the statically live bit window
    of concrete keys (⌈k_eff/d⌉ passes); traced keys always get the full
    ⌈k/d⌉ schedule.  Bits outside the window are globally constant, so the
    elided passes were identity permutations — output and stability are
    unchanged.  ``return_passes`` appends the executed pass count (a Python
    int) to the return value.
    """
    if keys.ndim != 1:
        raise ValueError("lsd_sort expects a 1-D key array")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # auto-resolved "kernel" engages under interpret mode only (core.plan)
    engine = plan.resolve_pass_engine(engine, interpret)
    k = bijection.key_bits(keys.dtype)
    if keys.shape[0] == 0:
        out = keys if values is None else (keys, values)
        if return_passes:
            return (*((out,) if values is None else out), 0)
        return out
    lo, hi = 0, k
    if adaptive and not isinstance(keys, jax.core.Tracer):
        lo, hi = hybrid.live_bit_window(bijection.to_ordered_bits_np(
            np.asarray(keys)))
    ukeys = bijection.to_ordered_bits(keys)
    vals = values if values is not None else ()
    ukeys, vals = _lsd_sort_bits(ukeys, vals, d, hi, engine, kpb, step_batch,
                                 interpret, lo=lo)
    out = bijection.from_ordered_bits(ukeys, keys.dtype)
    result = out if values is None else (out, vals)
    if return_passes:
        passes = model.num_digits(max(hi - lo, 0), d)
        return (*((result,) if values is None else result), passes)
    return result


# --- contract declaration (verified by repro.analysis; see analysis/contracts)
# The LSD driver unrolls its schedule: ⌈k/d⌉ fused launches + one prologue
# histogram, no device loop, every fused launch on the batched ⌈g_max/B⌉ grid.
ANALYSIS_CONTRACT = {
    "entry": "repro.core.lsd.lsd_sort",
    "census": {
        "launch_total": "passes + 1",
        "while_body_launches": "[]",
        "fused_grid": "ceil_div(g_max, B)",
    },
    "sort_free": True,
    "donation": {"_fused_pass_kernel": "1 + vals"},
    "transfer": {
        "sweep_kernels": ["_hist_kernel", "_fused_pass_kernel"],
        "bytes": "(2 * passes + 1) * n_pad * kb + 2 * passes * n_pad * vb",
    },
}
