"""LSD radix sort baseline — the CUB/Merrill analogue (paper §3).

State-of-the-art GPU radix sorts are least-significant-digit-first with d = 4
or 5 bits per *stable* pass (CUB 1.5.1: d=5; CUB 1.6.4 appendix: up to d=7).
This module is the measured baseline the hybrid sort is compared against: the
pass structure (⌈k/d⌉ stable counting passes, each reading the input twice and
writing once) is what produces the paper's 1.6–1.75x traffic ratio.

``lsd_sort`` routes through the same engine selector as ``hybrid_sort``:
``argsort``/``scan`` compute each pass's permutation in jnp, ``kernel`` runs
the Pallas tile-multisplit pipeline (shifts are static here, so the passes
unroll and feed the kernels directly).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bijection, model
from repro.core.ranks import resolve_engine, stable_partition_dest
from repro.kernels.ops import apply_run_copies, kernel_pass_perm


@functools.partial(jax.jit, static_argnames=("d", "k", "engine", "kpb",
                                             "interpret"))
def _lsd_sort_bits(ukeys, vals, d: int, k: int, engine: str, kpb: int,
                   interpret: bool):
    nd = model.num_digits(k, d)
    udt = ukeys.dtype

    if engine == "kernel":
        # LSD shifts are compile-time constants, so the pass loop unrolls and
        # each pass is one multisplit launch + run copies (src/dst pairs).
        for p in range(nd):
            shift = p * d
            width = min(d, k - shift)  # partial top digit on the last pass
            src, dst = kernel_pass_perm(ukeys, shift, width, k, kpb=kpb,
                                        interpret=interpret)
            ukeys, vals = apply_run_copies(src, dst, (ukeys, vals))
        return ukeys, vals

    def body(p, state):
        ukeys, vals = state
        shift = jnp.array(p * d, udt)
        # handle partial top digit: pass p covers bits [p*d, min((p+1)*d, k))
        width = jnp.minimum(d, k - p * d).astype(udt)
        mask = ((jnp.array(1, udt) << width) - 1).astype(udt)
        digit = ((ukeys >> shift) & mask).astype(jnp.int32)
        dest = stable_partition_dest(digit, 1 << d, engine=engine)
        ukeys = jnp.zeros_like(ukeys).at[dest].set(ukeys)
        vals = jax.tree.map(lambda v: jnp.zeros_like(v).at[dest].set(v), vals)
        return ukeys, vals

    ukeys, vals = lax.fori_loop(0, nd, body, (ukeys, vals))
    return ukeys, vals


def lsd_sort(keys: jnp.ndarray, values: Any = None, d: int = 5,
             engine: Optional[str] = None, kpb: int = 1024,
             interpret: Optional[bool] = None):
    """Stable LSD radix sort with ``d``-bit digits (default 5 — the CUB proxy).

    ``engine`` is resolved like ``hybrid_sort``'s (``argsort``/``scan``/
    ``kernel``/``auto``); ``kpb`` is the kernel engine's keys-per-block.
    """
    if keys.ndim != 1:
        raise ValueError("lsd_sort expects a 1-D key array")
    engine = resolve_engine(engine)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = bijection.key_bits(keys.dtype)
    if keys.shape[0] == 0:
        return keys if values is None else (keys, values)
    ukeys = bijection.to_ordered_bits(keys)
    vals = values if values is not None else ()
    ukeys, vals = _lsd_sort_bits(ukeys, vals, d, k, engine, kpb, interpret)
    out = bijection.from_ordered_bits(ukeys, keys.dtype)
    return out if values is None else (out, vals)
