"""Key-distribution generators for the paper's benchmarks (§6).

``entropy_keys`` implements the Thearling & Smith entropy-reduction benchmark:
repeatedly AND uniform draws; for 32-bit keys 0..3 ANDs give entropies of
32.00, 25.95, 17.41, 10.78 bits (the paper's x-axis).  ``zipf_keys`` matches
the PARADIS comparison (§6.2).
"""
from __future__ import annotations

import numpy as np


ENTROPY_BITS_32 = {0: 32.0, 1: 25.95, 2: 17.41, 3: 10.78, 4: 6.42, 5: 3.68,
                   6: 2.07, 7: 1.15, 8: 0.63, 9: 0.34, 10: 0.18}


def entropy_keys(rng: np.random.Generator, n: int, ands: int,
                 dtype=np.uint32) -> np.ndarray:
    info = np.iinfo(dtype)
    x = rng.integers(0, info.max, n, dtype=dtype, endpoint=True)
    for _ in range(ands):
        x &= rng.integers(0, info.max, n, dtype=dtype, endpoint=True)
    return x


def constant_keys(n: int, value: int = 0, dtype=np.uint32) -> np.ndarray:
    return np.full(n, value, dtype=dtype)


def zipf_keys(rng: np.random.Generator, n: int, a: float = 1.2,
              dtype=np.uint32) -> np.ndarray:
    info = np.iinfo(dtype)
    x = rng.zipf(a, n)
    return np.minimum(x, info.max).astype(dtype)
