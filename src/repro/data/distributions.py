"""Key-distribution generators for the paper's benchmarks (§5–§6).

Every generator threads an *explicit* PRNG — an ``np.random.Generator`` or a
plain int seed — and an explicit dtype.  Passing a seed makes a call
replayable in isolation: two benchmark rows built from the same seed get the
same keys no matter what ran in between, where the old shared-``Generator``
style silently coupled every row to the consumption order of its
predecessors (and tempted call sites into the global ``np.random`` state).
``as_generator`` is the one conversion point; ``None`` is rejected on
purpose — an OS-entropy default would un-fix exactly that.

``entropy_keys`` implements the Thearling & Smith entropy-reduction
benchmark: repeatedly AND uniform draws; for 32-bit keys 0..3 ANDs give
entropies of 32.00, 25.95, 17.41, 10.78 bits (the paper's x-axis).
``zipf_keys`` matches the PARADIS comparison (§6.2) and ``clustered_keys``
the skewed near-sorted inputs the §5 out-of-core pipeline is benchmarked
against (heavy duplication inside narrow key ranges — the distribution that
stresses merge-path tie handling).
"""
from __future__ import annotations

import numpy as np


ENTROPY_BITS_32 = {0: 32.0, 1: 25.95, 2: 17.41, 3: 10.78, 4: 6.42, 5: 3.68,
                   6: 2.07, 7: 1.15, 8: 0.63, 9: 0.34, 10: 0.18}


def as_generator(rng) -> np.random.Generator:
    """Explicit PRNG threading: an int seed or a ``Generator`` — never the
    global ``np.random`` state, never OS entropy."""
    if isinstance(rng, np.random.Generator):
        return rng
    if isinstance(rng, (int, np.integer, np.random.SeedSequence)):
        return np.random.default_rng(rng)
    raise TypeError(
        f"pass an int seed or np.random.Generator, got {type(rng).__name__}; "
        "implicit/global PRNG state is not supported")


def entropy_keys(rng, n: int, ands: int, dtype=np.uint32) -> np.ndarray:
    rng = as_generator(rng)
    info = np.iinfo(dtype)
    x = rng.integers(0, info.max, n, dtype=dtype, endpoint=True)
    for _ in range(ands):
        x &= rng.integers(0, info.max, n, dtype=dtype, endpoint=True)
    return x


def constant_keys(n: int, value: int = 0, dtype=np.uint32) -> np.ndarray:
    return np.full(n, value, dtype=dtype)


def zipf_keys(rng, n: int, a: float = 1.2, dtype=np.uint32) -> np.ndarray:
    rng = as_generator(rng)
    x = rng.zipf(a, n)                       # int64 samples
    cap = min(np.iinfo(dtype).max, np.iinfo(x.dtype).max)
    return np.minimum(x, cap).astype(dtype)


def clustered_keys(rng, n: int, clusters: int = 64, spread: int = 1 << 16,
                   dtype=np.uint32) -> np.ndarray:
    """Keys piled around a few uniform cluster centres (§5's skewed input).

    Each key is a uniformly chosen centre plus a uniform offset in
    ``[0, spread)`` — massive duplication of high digits inside narrow
    ranges, the case where MSD passes finish early and the out-of-core merge
    sees long equal-key ties across runs.
    """
    rng = as_generator(rng)
    info = np.iinfo(dtype)
    centers = rng.integers(0, info.max, clusters, dtype=dtype, endpoint=True)
    idx = rng.integers(0, clusters, n)
    # unsigned arithmetic end to end: uint64 + int64 would promote to
    # float64 and round 64-bit keys to 53-bit mantissas
    off = rng.integers(0, max(1, spread), n, dtype=np.uint64)
    return (centers[idx].astype(np.uint64) + off).astype(dtype)
