from repro.data.pipeline import SyntheticLMData, length_bucketed_batches
from repro.data.distributions import (as_generator, clustered_keys,
                                      constant_keys, entropy_keys, zipf_keys)

__all__ = ["SyntheticLMData", "length_bucketed_batches", "as_generator",
           "clustered_keys", "constant_keys", "entropy_keys", "zipf_keys"]
