from repro.data.pipeline import SyntheticLMData, length_bucketed_batches
from repro.data.distributions import entropy_keys, zipf_keys

__all__ = ["SyntheticLMData", "length_bucketed_batches", "entropy_keys",
           "zipf_keys"]
