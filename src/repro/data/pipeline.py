"""Data pipeline: restart-exact synthetic LM stream + sort-based bucketing.

Restart-exactness is the fault-tolerance contract: batch t is a pure function
of (seed, t), so resuming from a checkpoint at step t replays the identical
stream with no pipeline state to persist — counter-based PRNG keys, the same
pattern large-scale deterministic loaders use.

Length bucketing uses the hybrid radix sort (16-bit lengths = two d=8 counting
passes) to order documents by length before packing — the data-pipeline
integration point of the paper's technique.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hybrid import hybrid_sort


@dataclasses.dataclass
class SyntheticLMData:
    """Deterministic synthetic token stream: batch(step) is pure in (seed, step)."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_patches: int = 0          # vlm stub: also emit patch embeddings
    d_model: int = 0

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        # zipfian-ish token marginals: realistic softmax targets, cheap to make
        k1, k2 = jax.random.split(key)
        u = jax.random.uniform(k1, (self.global_batch, self.seq_len),
                               minval=1e-6, maxval=1.0)
        tokens = jnp.clip((self.vocab ** u - 1.0).astype(jnp.int32),
                          0, self.vocab - 1)
        out = {"tokens": tokens}
        if self.num_patches:
            out["patches"] = jax.random.normal(
                k2, (self.global_batch, self.num_patches, self.d_model),
                jnp.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def length_bucketed_batches(lengths: np.ndarray, batch_tokens: int):
    """Order documents by length with the hybrid sort, then greedily pack.

    Returns (order, bucket_bounds): ``order`` is the sorted document order
    (longest-with-longest minimises padding waste), bounds delimit batches of
    at most ``batch_tokens`` padded tokens.
    """
    lengths = np.asarray(lengths, np.uint32)
    doc_ids = jnp.arange(lengths.shape[0], dtype=jnp.int32)
    sorted_len, order = hybrid_sort(jnp.asarray(lengths), doc_ids)
    sorted_len = np.asarray(sorted_len)
    order = np.asarray(order)

    bounds = [0]
    cur_max = 0
    cur_n = 0
    for i, ln in enumerate(sorted_len):
        cand_max = max(cur_max, int(ln))
        if cur_n and cand_max * (cur_n + 1) > batch_tokens:
            bounds.append(i)
            cur_max, cur_n = int(ln), 1
        else:
            cur_max, cur_n = cand_max, cur_n + 1
    bounds.append(len(sorted_len))
    return order, bounds
