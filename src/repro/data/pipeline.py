"""Data pipeline: restart-exact synthetic LM stream + sort-based bucketing.

Restart-exactness is the fault-tolerance contract: batch t is a pure function
of (seed, t), so resuming from a checkpoint at step t replays the identical
stream with no pipeline state to persist — counter-based PRNG keys, the same
pattern large-scale deterministic loaders use.

Length bucketing runs explicit d=8 counting passes through
``core.segmented.counting_partition`` — the same engine-selected partition
primitive as MoE dispatch and the distributed sort's shard step
(``core.plan.single_pass_partition``; fused Pallas kernel under interpret
mode, XLA stable sort on compiled hardware until the Mosaic lowering lands).
Corpora larger than one device run route through the §5 out-of-core
pipeline instead (``ooc_chunk_elems``): shard-sized batches are ordered by
``core.outofcore.oocsort`` — chunked device sorts under double-buffered
staging plus the streaming k-way merge — so bucketing scales past device
memory with the same packing contract.  Corpora whose *runs* no longer fit
device memory additionally set ``ooc_spill_budget_bytes``: the merge phase
then streams host-resident runs through budget-bounded device slabs (the
§5 beyond-device-memory regime), still the same packing contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.segmented import counting_partition


@dataclasses.dataclass
class SyntheticLMData:
    """Deterministic synthetic token stream: batch(step) is pure in (seed, step)."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_patches: int = 0          # vlm stub: also emit patch embeddings
    d_model: int = 0

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        # zipfian-ish token marginals: realistic softmax targets, cheap to make
        k1, k2 = jax.random.split(key)
        u = jax.random.uniform(k1, (self.global_batch, self.seq_len),
                               minval=1e-6, maxval=1.0)
        tokens = jnp.clip((self.vocab ** u - 1.0).astype(jnp.int32),
                          0, self.vocab - 1)
        out = {"tokens": tokens}
        if self.num_patches:
            out["patches"] = jax.random.normal(
                k2, (self.global_batch, self.num_patches, self.d_model),
                jnp.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def length_bucketed_batches(lengths: np.ndarray, batch_tokens: int,
                            engine: Optional[str] = None,
                            ooc_chunk_elems: Optional[int] = None,
                            ooc_spill_budget_bytes: Optional[int] = None,
                            ooc_device_slab_elems: Optional[int] = None,
                            ooc_fault_policy=None,
                            ooc_retry_policy=None,
                            ooc_checkpoint_dir: Optional[str] = None,
                            dist_mesh=None,
                            dist_axis: str = "data"):
    """Order documents by length via two LSD counting passes, then pack.

    The ordering is an explicit LSD radix sort on the shared engine-selected
    partition primitive: chained d=8 ``counting_partition`` passes, one per
    occupied length byte (typical 16-bit lengths: two passes).  Corpora that
    exceed one device run set ``ooc_chunk_elems``: the order then comes from
    the §5 out-of-core pipeline (``core.outofcore.oocsort`` with the doc
    indices as the value payload — chunk sorts overlapped with staging, then
    streaming k-way merge rounds).  ``ooc_spill_budget_bytes`` /
    ``ooc_device_slab_elems`` pass through to ``oocsort``'s host-spill
    streaming merge, bounding device bytes for corpora whose sorted runs
    exceed device memory.  ``ooc_fault_policy`` / ``ooc_retry_policy`` /
    ``ooc_checkpoint_dir`` pass through to ``oocsort``'s resilience layer
    (``core.faults``): the bucketing order inherits bounded retries, the
    degradation ladder and round-granular checkpointing, so a multi-round
    corpus sort that dies mid-merge resumes instead of restarting — the
    same restart-exactness posture as the token stream itself.

    Corpora sharded across a device mesh route through the §5 distributed
    exchange instead (``dist_mesh=``, exclusive with the ooc route): doc
    indices ride ``core.distributed.make_distributed_sort`` as the value
    payload over the ``dist_axis`` mesh axis (sample-sort splitters, one
    fused counting pass per shard, capacity-padded all_to_all, bounded
    splitter-refinement retries on overflow), and the per-shard valid
    prefixes concatenate back into the global order.
    Returns (order, bucket_bounds):
    ``order`` is the sorted document order (longest-with-longest minimises
    padding waste), bounds delimit batches of at most ``batch_tokens``
    padded tokens.
    """
    lengths = np.asarray(lengths, np.uint32)
    if ooc_chunk_elems is None and (ooc_spill_budget_bytes is not None or
                                    ooc_device_slab_elems is not None):
        raise ValueError("ooc spill options require ooc_chunk_elems (the "
                         "spill regime is part of the out-of-core route)")
    if ooc_chunk_elems is None and (ooc_fault_policy is not None or
                                    ooc_retry_policy is not None or
                                    ooc_checkpoint_dir is not None):
        raise ValueError("ooc fault/retry/checkpoint options require "
                         "ooc_chunk_elems (resilience wraps the "
                         "out-of-core route)")
    if dist_mesh is not None and ooc_chunk_elems is not None:
        raise ValueError("dist_mesh and ooc_chunk_elems are exclusive "
                         "routes (mesh-sharded vs host-chunked ordering)")
    if dist_mesh is not None:
        from repro.core.distributed import make_distributed_sort, valid_concat
        nshards = dist_mesh.shape[dist_axis]
        n = lengths.shape[0]
        pad = (-n) % nshards
        # sentinel-pad to a shardable length; pads sort last and are dropped
        # below by index, so a real 0xFFFFFFFF length still buckets correctly
        keys = np.concatenate(
            [lengths, np.full(pad, np.uint32(0xFFFFFFFF), np.uint32)])
        idx = np.arange(n + pad, dtype=np.int32)
        # tiny shards: full-fan exchange capacity (slack = nshards caps each
        # cell at the whole chunk) so a small corpus can never overflow on
        # per-cell noise; the memory cost is n·nshards elements, trivial at
        # this scale, and large shards keep the sampled-splitter default
        n_local = (n + pad) // nshards
        slack = float(nshards) if n_local < 1024 else 2.0
        fn = jax.jit(make_distributed_sort(dist_mesh, dist_axis,
                                           slack=slack, engine=engine))
        out, order_out, stats = fn(jnp.asarray(keys), jnp.asarray(idx))
        if bool(np.asarray(stats.overflow).any()):
            raise RuntimeError("distributed length bucketing overflowed its "
                               "exchange capacity after splitter-refinement "
                               "retries (raise slack= or oversample=)")
        sorted_all = valid_concat(out, stats.valid)
        order_all = valid_concat(order_out, stats.valid)
        keep = order_all < n
        sorted_len, order = sorted_all[keep], order_all[keep]
    elif ooc_chunk_elems is not None:
        from repro.core.outofcore import oocsort
        sorted_len, order = oocsort(
            lengths, ooc_chunk_elems, engine=engine,
            values=np.arange(lengths.shape[0], dtype=np.int32),
            spill_budget_bytes=ooc_spill_budget_bytes,
            device_slab_elems=ooc_device_slab_elems,
            faults=ooc_fault_policy, retry=ooc_retry_policy,
            checkpoint_dir=ooc_checkpoint_dir)
    else:
        # host-side: only as many passes as the longest document needs
        max_len = int(lengths.max()) if lengths.size else 0
        npasses = max(1, (max_len.bit_length() + 7) // 8)
        x = lengths.copy()
        order = np.arange(lengths.shape[0], dtype=np.int32)
        for p in range(npasses):  # stable LSD, least-significant byte first
            ids = jnp.asarray(((x >> (8 * p)) & 0xFF).astype(np.int32))
            perm = np.asarray(counting_partition(ids, 256,
                                                 engine=engine).perm)
            x = x[perm]
            order = order[perm]
        sorted_len = x

    bounds = [0]
    cur_max = 0
    cur_n = 0
    for i, ln in enumerate(sorted_len):
        cand_max = max(cur_max, int(ln))
        if cur_n and cand_max * (cur_n + 1) > batch_tokens:
            bounds.append(i)
            cur_max, cur_n = int(ln), 1
        else:
            cur_max, cur_n = cand_max, cur_n + 1
    bounds.append(len(sorted_len))
    return order, bounds


# --- contract declaration (verified by repro.analysis; see analysis/contracts)
# Length bucketing partitions ids into 256 buckets with ONE counting pass
# (prologue histogram + fused launch), iota payload as the value leaf — the
# data-pipeline consumer of the same partition primitive.
ANALYSIS_CONTRACT = {
    "entry": "repro.core.segmented.counting_partition",
    "census": {
        "launch_total": "2",
        "while_body_launches": "[]",
        "fused_grid": "ceil_div(g_max, B)",
    },
    "sort_free": True,
    "donation": {"_fused_pass_kernel": "1 + vals"},
    "transfer": {
        "sweep_kernels": ["_hist_kernel", "_fused_pass_kernel"],
        "bytes": "(2 * passes + 1) * n_pad * kb + 2 * passes * n_pad * vb",
    },
}
