"""Checkpointing: content-hashed, zstd-compressed, async-capable, resumable.

Fault-tolerance contract:
  * atomic publish — a checkpoint directory becomes visible only after its
    ``manifest.json`` (with per-chunk sha256) is written via rename;
  * integrity — restore verifies hashes, refuses truncated writes (a killed
    writer never corrupts training);
  * async — ``AsyncCheckpointer`` snapshots device arrays to host
    (``jax.device_get``) synchronously (cheap) and does the compress+write on
    a background thread so the train loop never blocks on disk;
  * multi-host posture: each process writes its own shard directory keyed by
    ``jax.process_index()`` — on this single-process container that is shard 0.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import msgpack
import numpy as np

try:
    import zstandard as zstd
except ModuleNotFoundError:          # optional dep: fall back to stdlib zlib
    import zlib

    class _ZlibShim:
        """Minimal ZstdCompressor/Decompressor stand-in (same call surface).

        Chunks written by one codec are only readable by the same codec; on a
        container without ``zstandard`` the checkpoints are zlib streams under
        the same file names.
        """
        class ZstdCompressor:
            def __init__(self, level: int = 3):
                self._level = level

            def compress(self, blob: bytes) -> bytes:
                return zlib.compress(blob, self._level)

        class ZstdDecompressor:
            def decompress(self, comp: bytes) -> bytes:
                if comp[:4] == b"\x28\xb5\x2f\xfd":   # zstd frame magic
                    raise IOError(
                        "checkpoint chunk is a zstd frame but the 'zstandard' "
                        "module is not installed (saved on another machine?)")
                return zlib.decompress(comp)

    zstd = _ZlibShim()

import jax
import jax.numpy as jnp


def _tree_to_payload(tree):
    leaves, treedef = jax.tree.flatten(tree)
    arrs = [np.asarray(x) for x in leaves]
    meta = [{"dtype": str(a.dtype), "shape": list(a.shape)} for a in arrs]
    blobs = [a.tobytes() for a in arrs]
    return treedef, meta, blobs


def save_checkpoint(directory: str, step: int, tree: Any, keep: int = 3):
    """Write checkpoint for ``step``; prune to the newest ``keep``."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    treedef, meta, blobs = _tree_to_payload(tree)
    cctx = zstd.ZstdCompressor(level=3)
    hashes = []
    for i, blob in enumerate(blobs):
        comp = cctx.compress(blob)
        hashes.append(hashlib.sha256(comp).hexdigest())
        with open(os.path.join(tmp, f"chunk_{i:06d}.zst"), "wb") as f:
            f.write(comp)
    manifest = {"step": step, "num_chunks": len(blobs), "meta": meta,
                "treedef": str(treedef), "hashes": hashes,
                "process": jax.process_index()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # structure is stored via msgpack of the flatten-with-path key list
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    with open(os.path.join(tmp, "paths.msgpack"), "wb") as f:
        f.write(msgpack.packb(paths))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish

    steps = sorted(latest_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)
    return final


def latest_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = latest_steps(directory)
    return steps[-1] if steps else None


def restore_blind(directory: str, step: int) -> dict:
    """Restore a checkpoint without a ``like`` tree.

    Rebuilds every chunk as a host numpy array straight from the manifest's
    per-chunk dtype/shape meta (hash-verified, no device trip) and returns a
    ``{keystr: array}`` mapping keyed by the flatten-with-path key strings
    recorded in ``paths.msgpack``.  This is what a *resuming* process needs:
    it has no live pytree to mirror — the checkpoint is the only source of
    structure.  Used by ``core.outofcore``'s round-granular resume, where
    the tree is a flat dict of merge runs plus a JSON manifest leaf.
    """
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "paths.msgpack"), "rb") as f:
        paths = msgpack.unpackb(f.read())
    assert len(paths) == manifest["num_chunks"], "paths/manifest mismatch"
    dctx = zstd.ZstdDecompressor()
    out = {}
    for i, (keystr, meta) in enumerate(zip(paths, manifest["meta"])):
        with open(os.path.join(path, f"chunk_{i:06d}.zst"), "rb") as f:
            comp = f.read()
        if hashlib.sha256(comp).hexdigest() != manifest["hashes"][i]:
            raise IOError(f"checkpoint chunk {i} corrupt")
        out[keystr] = np.frombuffer(
            dctx.decompress(comp),
            dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])
    return out


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype verified)."""
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    dctx = zstd.ZstdDecompressor()
    leaves, treedef = jax.tree.flatten(like)
    assert manifest["num_chunks"] == len(leaves), "tree structure changed"
    out = []
    for i, (ref, meta) in enumerate(zip(leaves, manifest["meta"])):
        with open(os.path.join(path, f"chunk_{i:06d}.zst"), "rb") as f:
            comp = f.read()
        if hashlib.sha256(comp).hexdigest() != manifest["hashes"][i]:
            raise IOError(f"checkpoint chunk {i} corrupt")
        arr = np.frombuffer(dctx.decompress(comp),
                            dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])
        assert tuple(arr.shape) == tuple(np.shape(ref)), f"shape drift chunk {i}"
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
