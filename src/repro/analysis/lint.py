"""AST lint: source-level contract rules the jaxpr can't see per-call-site.

Three rules, each scoped to the layer whose contract it protects:

  R1 ``no-comparison-sort`` — the kernel-engine modules
     (``src/repro/kernels/``) must never call ``sort``/``argsort``/
     ``lexsort``: the engines are sort-free by construction and a smuggled
     ``jnp.sort`` would silently satisfy every parity test while voiding
     the paper's claim.  ``kernels/ref.py`` is the declared jnp oracle and
     is allowlisted.
  R2 ``no-global-prng`` — ``src/repro/data/`` threads explicit
     ``np.random.Generator``s (the reproducibility contract of the data
     layer); module-level ``np.random.<draw>`` or ``random.<draw>`` calls
     are forbidden (``default_rng``/``Generator``/``SeedSequence``
     constructors are the sanctioned spellings).
  R3 ``undonated-dispatch`` — any function taking alternate ping-pong
     buffers (an ``alt_*`` parameter) that builds a ``pl.pallas_call`` must
     pass ``input_output_aliases``; forgetting it is the silent-copy bug
     the donation audit catches at trace time, caught here at the source.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import List, Sequence

SORT_NAMES = frozenset({"sort", "argsort", "lexsort", "sort_complex",
                        "msort"})
PRNG_OK = frozenset({"default_rng", "Generator", "SeedSequence",
                     "BitGenerator", "PCG64", "Philox", "RandomState"})
_PRNG_MODULES = ("np.random", "numpy.random", "random")
SORT_ALLOWLIST = ("ref.py",)


@dataclass
class LintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _dotted(node: ast.AST) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def lint_no_comparison_sort(tree: ast.AST, path: str) -> List[LintFinding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in SORT_NAMES:
            out.append(LintFinding(
                "no-comparison-sort", path, node.lineno,
                f"call to .{node.func.attr}() in a kernel-engine module "
                f"(sort-free contract; use kernels/ref.py for oracles)"))
    return out


def lint_no_global_prng(tree: ast.AST, path: str) -> List[LintFinding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            for mod in _PRNG_MODULES:
                prefix = mod + "."
                if dotted.startswith(prefix):
                    leaf = dotted[len(prefix):].split(".")[0]
                    if leaf not in PRNG_OK:
                        out.append(LintFinding(
                            "no-global-prng", path, node.lineno,
                            f"global-PRNG call {dotted}() — thread an "
                            f"explicit np.random.Generator instead"))
        elif isinstance(node, ast.ImportFrom) and \
                node.module in ("numpy.random", "random"):
            bad = [a.name for a in node.names if a.name not in PRNG_OK]
            if bad:
                out.append(LintFinding(
                    "no-global-prng", path, node.lineno,
                    f"imports global-PRNG names {bad} from {node.module}"))
    return out


def _has_alt_param(fn: ast.AST) -> bool:
    args = fn.args
    every = (args.posonlyargs + args.args + args.kwonlyargs +
             ([args.vararg] if args.vararg else []))
    return any(a.arg.startswith("alt_") for a in every)


def lint_donated_dispatch(tree: ast.AST, path: str) -> List[LintFinding]:
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not _has_alt_param(fn):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    _dotted(node.func).endswith("pallas_call"):
                kw = {k.arg for k in node.keywords}
                if "input_output_aliases" not in kw:
                    out.append(LintFinding(
                        "undonated-dispatch", path, node.lineno,
                        f"{fn.name}() takes alt_* ping-pong buffers but its "
                        f"pallas_call passes no input_output_aliases — the "
                        f"alternate buffer will silently copy"))
    return out


_RULES = {
    "no-comparison-sort": lint_no_comparison_sort,
    "no-global-prng": lint_no_global_prng,
    "undonated-dispatch": lint_donated_dispatch,
}


def lint_source(src: str, path: str,
                rules: Sequence[str] = tuple(_RULES)) -> List[LintFinding]:
    """Lint one source string under the named rules (mutation-test entry)."""
    tree = ast.parse(src, filename=path)
    out: List[LintFinding] = []
    for rule in rules:
        out.extend(_RULES[rule](tree, path))
    return out


def lint_file(path: str, rules: Sequence[str]) -> List[LintFinding]:
    with open(path, "r", encoding="utf-8") as f:
        return lint_source(f.read(), path, rules)


def run_lint(src_root: str) -> List[LintFinding]:
    """Lint the repo layers under their scoped rules.

    ``src_root`` is the ``src/repro`` package directory.  Kernel-engine
    modules get R1 (+R3); the data layer gets R2.
    """
    out: List[LintFinding] = []
    kdir = os.path.join(src_root, "kernels")
    for name in sorted(os.listdir(kdir)):
        if not name.endswith(".py"):
            continue
        rules = ["undonated-dispatch"]
        if name not in SORT_ALLOWLIST:
            rules.append("no-comparison-sort")
        out.extend(lint_file(os.path.join(kdir, name), rules))
    ddir = os.path.join(src_root, "data")
    for name in sorted(os.listdir(ddir)):
        if name.endswith(".py"):
            out.extend(lint_file(os.path.join(ddir, name),
                                 ["no-global-prng"]))
    return out
