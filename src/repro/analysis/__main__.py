"""CLI driver: ``python -m repro.analysis [--json REPORT.json]``.

Runs every registered contract (census, sort-free, donation, transfer,
link, ref-hazard), the descriptor-table interval checks, and the source
lint; prints a per-check summary and exits non-zero on any finding.
Compile-only — no kernel executes, so the sweep stays CI-fast.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="verify the declared kernel contracts against the "
                    "traced jaxprs (no execution)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a machine-readable report to PATH")
    ap.add_argument("--only", metavar="NAME", default=None,
                    help="run a single contract by registry name")
    args = ap.parse_args(argv)

    from repro.analysis import contracts, lint

    t0 = time.time()
    if args.only:
        if args.only not in contracts.REGISTRY:
            ap.error(f"unknown contract {args.only!r}; have "
                     f"{sorted(contracts.REGISTRY)}")
        reports = [contracts.run_contract(contracts.REGISTRY[args.only])]
    else:
        reports = contracts.run_all()

    src_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    lint_findings = lint.run_lint(src_root)

    failures = 0
    for rep in reports:
        nchecks = len(rep.checks)
        if rep.ok:
            print(f"  PASS {rep.name} ({nchecks} checks)")
        else:
            failures += len(rep.findings)
            print(f"  FAIL {rep.name}")
            for f in rep.findings:
                print(f"       {f}")
    if lint_findings:
        failures += len(lint_findings)
        print("  FAIL lint")
        for f in lint_findings:
            print(f"       {f}")
    else:
        print(f"  PASS lint ({len(lint._RULES)} rules)")

    dt = time.time() - t0
    verdict = "GREEN" if failures == 0 else f"{failures} finding(s)"
    print(f"analysis: {len(reports)} contracts + lint in {dt:.1f}s — "
          f"{verdict}")

    if args.json:
        payload = {
            "ok": failures == 0,
            "seconds": round(dt, 2),
            "contracts": [rep.to_dict() for rep in reports],
            "lint": [vars(f) for f in lint_findings],
        }
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2)
        print(f"report written to {args.json}")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
