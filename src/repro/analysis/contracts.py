"""The contract registry: every public entry point, declaratively verified.

Each engine module co-locates a pure-data ``ANALYSIS_CONTRACT`` declaration
(census formulas, sort-free flag, donation counts, transfer formulas) next
to the code it constrains; this module binds those declarations to concrete
*trace recipes* — a representative input shape per entry point — and runs
every check against the traced jaxpr without executing anything.

A :class:`Contract` is (name, decl, make) where ``make() -> (fn, args,
params)``: ``fn(*args)`` is traced with ``jax.make_jaxpr`` and ``params``
is the symbolic-formula environment (passes, classes, n_pad, ...) built by
the exported ``*_params`` helpers — the same helpers the launch-census
tests use, so the tests and the analyzer can never drift apart.

``run_all()`` is the whole sweep (plus the descriptor-table interval
checks of :func:`table_checks`); ``python -m repro.analysis`` drives it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis import census as _census
from repro.analysis import donation as _donation
from repro.analysis import expr
from repro.analysis import refhazard
from repro.analysis import transfer as _transfer
from repro.analysis.trace import (collect_pallas_sites, collective_link_bytes,
                                  sort_primitive_count)
from repro.core import distributed as core_distributed
from repro.core import hybrid as core_hybrid
from repro.core import lsd as core_lsd
from repro.core import model, outofcore as core_outofcore, plan
from repro.core.hybrid import hybrid_sort, local_sort_classes
from repro.core.lsd import lsd_sort
from repro.core.outofcore import _sort_chunk, merge_round
from repro.core.segmented import capacity_dispatch, counting_partition
from repro.data import pipeline as data_pipeline
from repro.kernels import fused
from repro.kernels import merge as kmerge
from repro.models import moe as models_moe

# the launch-census test config: small thresholds so every structural
# feature (local-sort classes, multi-pass loop) appears at toy sizes
TCFG = model.SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)


# --------------------------------------------------------------------------
# symbolic-parameter builders (shared with tests/test_launch_count.py)

def hybrid_params(n: int, cfg: model.SortConfig, key_bits: int = 32,
                  key_bytes: int = 4, vals: int = 0,
                  val_bytes: int = 0) -> Dict[str, Any]:
    """Formula environment for the hybrid-sort contract at (n, cfg)."""
    a_max = model.max_active_buckets(n, cfg)
    return {
        "n": n,
        "classes": len(local_sort_classes(n, cfg)),
        "passes": model.num_digits(key_bits, cfg.d),
        "g_max": plan.max_region_blocks(n, cfg.kpb, a_max),
        "B": cfg.step_batch,
        "n_pad": fused.pad_length(n, cfg.kpb),
        "kb": key_bytes, "vb": val_bytes, "vals": vals,
    }


def lsd_params(n: int, d: int, kpb: int, step_batch: int, key_bits: int = 32,
               key_bytes: int = 4, vals: int = 0,
               val_bytes: int = 0) -> Dict[str, Any]:
    """Formula environment for the LSD contract (unrolled, a_max = 1)."""
    return {
        "n": n,
        "passes": model.num_digits(key_bits, d),
        "g_max": plan.max_region_blocks(n, kpb, 1),
        "B": step_batch,
        "n_pad": fused.pad_length(n, kpb),
        "kb": key_bytes, "vb": val_bytes, "vals": vals,
    }


def spp_params(m: int, num_buckets: int, kpb: int = 1024,
               step_batch: int = 8, id_bytes: int = 4) -> Dict[str, Any]:
    """Formula environment for one standalone counting pass
    (``plan.single_pass_partition`` and everything routed through it:
    ``counting_partition``, ``capacity_dispatch``, length bucketing).
    Mirrors the engine's kpb clamp; the iota permutation is the single
    int32 value leaf."""
    kpb_eff = max(8, min(kpb, 1 << (m - 1).bit_length()))
    return {
        "n": m,
        "passes": 1,
        "g_max": plan.max_region_blocks(m, kpb_eff, 1),
        "B": step_batch,
        "n_pad": fused.pad_length(m, kpb_eff),
        "kb": id_bytes, "vb": 4, "vals": 1,
    }


def merge_params(lens, kway: int, tile: int, key_bytes: int = 4,
                 vals: int = 0, val_bytes: int = 0) -> Dict[str, Any]:
    """Formula environment for one k-way merge round over runs ``lens``."""
    n = int(sum(lens))
    return {
        "n": n, "kway": kway,
        "n_pad": fused.pad_length(n, tile),
        "kb": key_bytes, "vb": val_bytes, "vals": vals,
    }


def dist_params(P: int, n_local: int, chunks: int, attempts: int,
                cfg: model.SortConfig, oversample: int = 64,
                slack: float = 2.0, refine: int = 4, key_bytes: int = 4,
                leaves: int = 0, val_bytes: int = 0) -> Dict[str, Any]:
    """Formula environment for the distributed shard body.

    Re-derives the engine's static shapes: per-(source, dest) capacity
    (slack + 4σ headroom, chunk-capped) and the per-attempt gathered
    sample lengths ``samp[a] = chunks * m_a`` (the all_gather rows).
    """
    chunk = n_local // chunks
    base = slack * chunk / P
    cap = max(1, min(chunk, int(base + 4.0 * math.sqrt(max(base, 1.0)))))
    samp = []
    for a in range(attempts):
        s_a = oversample * (refine ** a)
        m = max(1, min(-(-s_a // chunks), chunk))
        samp.append(chunks * m)
    return {
        "P": P, "chunks": chunks, "attempts": attempts,
        "classes": len(local_sort_classes(chunk, cfg)),
        "cap": cap, "samp": samp,
        "kb": key_bytes, "vb": val_bytes, "leaves": leaves,
    }


def expected_census(name: str, params: Dict[str, Any]) -> Dict[str, Any]:
    """Evaluate a registered contract's census formulas at ``params``.

    The launch-census tests call this instead of re-stating the integers:
    one declaration, checked by the analyzer AND exercised by the tests.
    """
    decl = REGISTRY[name].decl["census"]
    return {
        "total": int(expr.evaluate(decl["launch_total"], params)),
        "while_bodies": [int(x) for x in
                         expr.evaluate(decl["while_body_launches"], params)],
    }


# --------------------------------------------------------------------------
# contract records and trace recipes

@dataclass(frozen=True)
class Contract:
    """One verified entry point: declaration + trace recipe."""
    name: str
    decl: Dict[str, Any]
    make: Callable[[], Tuple[Callable, tuple, Dict[str, Any]]]


def _abstract_mesh(n: int, name: str):
    try:
        return jax.sharding.AbstractMesh((n,), (name,))
    except TypeError:                       # older ctor: ((name, size),)
        return jax.sharding.AbstractMesh(((name, n),))


def _mk_hybrid():
    n = 2048
    fn = lambda a: hybrid_sort(a, cfg=TCFG, engine="kernel")
    return fn, (jnp.zeros(n, jnp.uint32),), hybrid_params(n, TCFG)


def _mk_hybrid_kv():
    n = 1024
    fn = lambda a, b: hybrid_sort(a, b, cfg=TCFG, engine="kernel")
    return (fn, (jnp.zeros(n, jnp.uint32), jnp.zeros(n, jnp.int32)),
            hybrid_params(n, TCFG, vals=1, val_bytes=4))


def _mk_lsd():
    n, d, kpb, B = 2048, 8, 512, 4
    fn = lambda a: lsd_sort(a, d=d, engine="kernel", kpb=kpb, step_batch=B)
    return fn, (jnp.zeros(n, jnp.uint32),), lsd_params(n, d, kpb, B)


def _mk_spp():
    m, r = 1000, 8
    fn = lambda i: plan.single_pass_partition(i, r, engine="kernel")
    return fn, (jnp.zeros(m, jnp.int32),), spp_params(m, r)


def _mk_moe_dispatch():
    m, e, cap = 512, 8, 64
    fn = lambda i: capacity_dispatch(i, e, cap, engine="kernel")
    return fn, (jnp.zeros(m, jnp.int32),), spp_params(m, e)


def _mk_pipeline_bucketing():
    m, r = 600, 256
    fn = lambda i: counting_partition(i, r, engine="kernel")
    return fn, (jnp.zeros(m, jnp.int32),), spp_params(m, r)


def _mk_ooc_chunk_sort():
    n = 256
    fn = lambda a: _sort_chunk(a, (), TCFG, "kernel", True)
    return fn, (jnp.zeros(n, jnp.uint32),), hybrid_params(n, TCFG)


def _mk_ooc_merge_round():
    lens, kway, tile = (256,) * 4, 4, 64
    n = sum(lens)
    buf = fused.pad_length(n, tile)
    fn = lambda a, b: merge_round(a, (), b, (), lens=lens, kway=kway,
                                  tile=tile, n=n, interpret=True)
    return (fn, (jnp.zeros((buf,), jnp.uint32), jnp.zeros((buf,), jnp.uint32)),
            merge_params(lens, kway, tile))


def _mk_ooc_slab_sweep():
    # the §5 spill path: sentinel-pad an exact strip upload to the slab
    # buffer and run ONE merge-kernel sweep (mirrors the launch-count test)
    slab, tile, kway = 64, 16, 4
    buf = fused.pad_length(slab, tile)
    G = slab // tile
    sentinel = ~jnp.zeros((), jnp.uint32)

    def sweep(up_k, alt_k, off, cnt, ws, wt):
        slab_k = jnp.concatenate(
            [up_k, jnp.full((buf - up_k.shape[0],), sentinel, jnp.uint32)])
        return kmerge.kway_merge_round(slab_k, (), alt_k, (), off, cnt, ws,
                                       wt, kway=kway, tpb=tile, n=slab,
                                       interpret=True)

    args = (jnp.zeros((48,), jnp.uint32), jnp.full((buf,), sentinel),
            jnp.zeros((G,), jnp.int32), jnp.zeros((G,), jnp.int32),
            jnp.full((G * kway,), slab, jnp.int32),
            jnp.zeros((G * kway,), jnp.int32))
    return sweep, args, merge_params((slab,), kway, tile)


def _mk_distributed():
    P, n_local, chunks, attempts = 8, 512, 2, 2
    mesh = _abstract_mesh(P, "data")
    fn = core_distributed.make_distributed_sort(
        mesh, "data", cfg=TCFG, engine="kernel", num_chunks=chunks,
        max_attempts=attempts, oversample=64, slack=2.0, refine=4)
    return (fn, (jnp.zeros(P * n_local, jnp.uint32),),
            dist_params(P, n_local, chunks, attempts, TCFG))


CONTRACTS: List[Contract] = [
    Contract("hybrid_sort", core_hybrid.ANALYSIS_CONTRACT, _mk_hybrid),
    Contract("hybrid_sort_kv", core_hybrid.ANALYSIS_CONTRACT, _mk_hybrid_kv),
    Contract("lsd_sort", core_lsd.ANALYSIS_CONTRACT, _mk_lsd),
    Contract("single_pass_partition", plan.ANALYSIS_CONTRACT, _mk_spp),
    Contract("moe_dispatch", models_moe.ANALYSIS_CONTRACT, _mk_moe_dispatch),
    Contract("pipeline_bucketing", data_pipeline.ANALYSIS_CONTRACT,
             _mk_pipeline_bucketing),
    Contract("ooc_chunk_sort",
             core_outofcore.ANALYSIS_CONTRACTS["ooc_chunk_sort"],
             _mk_ooc_chunk_sort),
    Contract("ooc_merge_round",
             core_outofcore.ANALYSIS_CONTRACTS["ooc_merge_round"],
             _mk_ooc_merge_round),
    Contract("ooc_slab_sweep",
             core_outofcore.ANALYSIS_CONTRACTS["ooc_slab_sweep"],
             _mk_ooc_slab_sweep),
    Contract("distributed_shard", core_distributed.ANALYSIS_CONTRACT,
             _mk_distributed),
]
REGISTRY: Dict[str, Contract] = {c.name: c for c in CONTRACTS}


# --------------------------------------------------------------------------
# the runner

@dataclass
class ContractReport:
    """Per-contract findings, keyed by check name (empty lists = green)."""
    name: str
    checks: Dict[str, List[str]] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not any(self.checks.values())

    @property
    def findings(self) -> List[str]:
        return [f"{self.name}/{check}: {msg}"
                for check, msgs in self.checks.items() for msg in msgs]

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "ok": self.ok, "checks": self.checks}


def run_contract(contract: Contract) -> ContractReport:
    """Trace one entry point and run every declared check on the jaxpr."""
    fn, args, params = contract.make()
    jx = jax.make_jaxpr(fn)(*args)
    sites = collect_pallas_sites(jx)
    decl = contract.decl
    checks: Dict[str, List[str]] = {}

    if "census" in decl:
        checks["census"] = _census.check_census(jx, sites, decl["census"],
                                                params)
    if decl.get("sort_free"):
        nsorts = sort_primitive_count(jx)
        checks["sort_free"] = ([] if nsorts == 0 else
                               [f"{nsorts} sort primitive(s) in the trace of "
                                f"a sort-free entry point"])
    checks["donation"] = _donation.check_donation(sites, decl.get("donation"),
                                                  params)
    if "transfer" in decl:
        checks["transfer.hbm_bytes"] = _transfer.check_hbm_bytes(
            sites, decl["transfer"], params)
    if "link" in decl:
        checks["transfer.link_bytes"] = _transfer.check_link_bytes(
            collective_link_bytes(jx, params["P"]), decl["link"], params)
    checks["hazard"] = refhazard.sweep_kernels(sites)
    return ContractReport(contract.name, checks)


def table_checks() -> Dict[str, List[str]]:
    """Interval checks on descriptor-table instances from the real planners.

    The jaxpr-level hazard pass proves the kernels' access *shape*; these
    prove the scalar-prefetched tables driving them produce disjoint,
    exactly-covering ranges — fused region blocks, merge-path tiles, and
    host-spill strips.
    """
    out: Dict[str, List[str]] = {}

    m, kpb, B = 1000, 128, 4
    blocks = plan.make_region_blocks(
        jnp.zeros((1,), jnp.int32), jnp.full((1,), m, jnp.int32), m, kpb,
        plan.max_region_blocks(m, kpb, 1), batch=B)
    out["hazard.fused_tables"] = refhazard.check_fused_tables(
        blocks, m, kpb, fused.pad_length(m, kpb))

    lens, kway, tile = (64, 48, 32, 16, 40), 4, 16
    n = int(sum(lens))
    buf = fused.pad_length(n, tile)
    sentinel = ~jnp.zeros((), jnp.uint32)
    keys = jnp.concatenate(
        [jnp.arange(l, dtype=jnp.uint32) for l in lens] +
        [jnp.full((buf - n,), sentinel)])
    tables = kmerge.merge_path_partition(keys, lens, kway, tile)
    out["hazard.merge_tables"] = refhazard.check_merge_tables(
        *tables, kway=kway, tpb=tile, n=n, buf_len=buf)

    runs = [np.arange(l, dtype=np.uint32) for l in (100, 37, 23)]
    tile, slab = 16, 32
    spill: List[str] = []
    for strip in kmerge.spill_group_plan(runs, 4, tile, slab):
        spill.extend(refhazard.check_merge_tables(
            *strip.tables, kway=4, tpb=tile, n=strip.out_len,
            buf_len=fused.pad_length(slab, tile)))
    out["hazard.spill_tables"] = spill
    return out


def run_all() -> List[ContractReport]:
    """The full sweep: every registered contract + the table instances."""
    reports = [run_contract(c) for c in CONTRACTS]
    reports.append(ContractReport("descriptor_tables", table_checks()))
    return reports
