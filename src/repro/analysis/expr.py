"""Restricted symbolic-formula evaluation for contract declarations.

Contract registries declare launch censuses and byte budgets as *formulas*
over named structural parameters — ``"2 + classes"``,
``"(2 * passes + 1) * n_pad * kb"`` — instead of hard-coded integers, so one
declaration covers every (n, cfg) shape and the tests and the analyzer
evaluate the SAME source of truth.  Formulas are parsed with :mod:`ast` and
evaluated against an explicit parameter mapping under a small node/function
whitelist: no attribute access, no subscripted calls, no names outside the
parameters and the helper table.  Anything else is a declaration bug and
raises ``FormulaError`` at analysis time, never at import time.
"""
from __future__ import annotations

import ast
import math
from typing import Any, Dict


class FormulaError(ValueError):
    """A contract formula failed to parse or evaluate."""


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


_HELPERS = {
    "ceil_div": ceil_div,
    "len": len,
    "sum": sum,
    "min": min,
    "max": max,
    "abs": abs,
    "int": int,
    "range": range,
    "sqrt": math.sqrt,
}

_ALLOWED_NODES = (
    ast.Expression, ast.BinOp, ast.UnaryOp, ast.BoolOp, ast.Compare,
    ast.IfExp, ast.Call, ast.Name, ast.Constant, ast.List, ast.Tuple,
    ast.ListComp, ast.GeneratorExp, ast.comprehension, ast.Load,
    # operators
    ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv, ast.Mod, ast.Pow,
    ast.USub, ast.UAdd, ast.And, ast.Or, ast.Not,
    ast.Eq, ast.NotEq, ast.Lt, ast.LtE, ast.Gt, ast.GtE,
)


def _check(node: ast.AST, params: Dict[str, Any]) -> None:
    for sub in ast.walk(node):
        if not isinstance(sub, _ALLOWED_NODES):
            raise FormulaError(
                f"disallowed syntax {type(sub).__name__!r} in contract "
                f"formula")
        if isinstance(sub, ast.Call):
            if not isinstance(sub.func, ast.Name):
                raise FormulaError("only bare helper-name calls are allowed")
            if sub.func.id not in _HELPERS:
                raise FormulaError(f"unknown helper {sub.func.id!r} "
                                   f"(allowed: {sorted(_HELPERS)})")
            if sub.keywords:
                raise FormulaError("keyword arguments are not allowed")


def evaluate(formula: str, params: Dict[str, Any]) -> Any:
    """Evaluate a declaration formula against structural parameters.

    ``params`` maps bare names (``passes``, ``classes``, ``n_pad``, ...) to
    ints/floats/lists; comprehension-bound names shadow them.  Returns
    whatever the expression produces (int, float, or list — census formulas
    like ``"[1] * chunks"`` return lists).
    """
    try:
        tree = ast.parse(formula, mode="eval")
    except SyntaxError as e:
        raise FormulaError(f"unparsable contract formula {formula!r}: {e}")
    _check(tree, params)

    env = dict(_HELPERS)
    overlap = set(env) & set(params)
    if overlap:
        raise FormulaError(f"parameters shadow helpers: {sorted(overlap)}")
    env.update(params)

    def ev(node):
        if isinstance(node, ast.Expression):
            return ev(node.body)
        if isinstance(node, ast.Constant):
            if not isinstance(node.value, (int, float, bool)):
                raise FormulaError(
                    f"non-numeric literal {node.value!r} in formula")
            return node.value
        if isinstance(node, ast.Name):
            if node.id not in env:
                raise FormulaError(
                    f"unknown parameter {node.id!r} in {formula!r} "
                    f"(have: {sorted(params)})")
            return env[node.id]
        if isinstance(node, ast.UnaryOp):
            v = ev(node.operand)
            if isinstance(node.op, ast.USub):
                return -v
            if isinstance(node.op, ast.UAdd):
                return +v
            return not v
        if isinstance(node, ast.BinOp):
            a, b = ev(node.left), ev(node.right)
            op = type(node.op)
            return {ast.Add: lambda: a + b, ast.Sub: lambda: a - b,
                    ast.Mult: lambda: a * b, ast.Div: lambda: a / b,
                    ast.FloorDiv: lambda: a // b, ast.Mod: lambda: a % b,
                    ast.Pow: lambda: a ** b}[op]()
        if isinstance(node, ast.BoolOp):
            vals = [ev(v) for v in node.values]
            return all(vals) if isinstance(node.op, ast.And) else any(vals)
        if isinstance(node, ast.Compare):
            left = ev(node.left)
            for op, right_n in zip(node.ops, node.comparators):
                right = ev(right_n)
                ok = {ast.Eq: left == right, ast.NotEq: left != right,
                      ast.Lt: left < right, ast.LtE: left <= right,
                      ast.Gt: left > right, ast.GtE: left >= right}[type(op)]
                if not ok:
                    return False
                left = right
            return True
        if isinstance(node, ast.IfExp):
            return ev(node.body) if ev(node.test) else ev(node.orelse)
        if isinstance(node, ast.Call):
            return _HELPERS[node.func.id](*[ev(a) for a in node.args])
        if isinstance(node, (ast.List, ast.Tuple)):
            return [ev(e) for e in node.elts]
        if isinstance(node, (ast.ListComp, ast.GeneratorExp)):
            if len(node.generators) != 1:
                raise FormulaError("only single-generator comprehensions")
            gen = node.generators[0]
            if gen.is_async or not isinstance(gen.target, ast.Name):
                raise FormulaError("unsupported comprehension form")
            out = []
            saved = env.get(gen.target.id, _MISSING)
            for item in ev(gen.iter):
                env[gen.target.id] = item
                if all(ev(c) for c in gen.ifs):
                    out.append(ev(node.elt))
            if saved is _MISSING:
                env.pop(gen.target.id, None)
            else:
                env[gen.target.id] = saved
            return out
        raise FormulaError(f"unhandled node {type(node).__name__}")

    try:
        return ev(tree)
    except FormulaError:
        raise
    except Exception as e:                      # arithmetic/type errors
        raise FormulaError(f"formula {formula!r} failed to evaluate: {e}")


_MISSING = object()
