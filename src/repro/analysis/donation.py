"""Donation audit: every ping-pong / slab buffer must alias, never copy.

The §4.4 in-place replacement is only real if the alternate buffers are
donated: ``input_output_aliases`` must map each full-length alternate
operand onto its output, and the kernel body must never read the donated
ref (its contents are garbage the moment the output writes begin).  A
dropped alias is *silent* — the program stays correct, XLA just
materialises a fresh buffer and copies, which doubles the §4.3 write
traffic.  This pass makes that failure loud:

  * declared check — each kernel listed in the contract's ``donation``
    mapping must carry exactly the declared number of alias pairs,
  * structural checks on every alias pair — operand/output avals match and
    the aliased operand has zero ``get``s in the kernel body,
  * the silent-copy sweep — any *unaliased* 1-D output at least as large as
    the site's largest buffer operand, with an identically-shaped unaliased
    operand available to donate, is flagged (that is exactly the shape of a
    forgotten ping-pong alias; accumulator outputs and the 2-D bitonic
    class tables don't trip it).
"""
from __future__ import annotations

from typing import Dict, List

from repro.analysis import expr
from repro.analysis.trace import PallasSite, ref_access_counts


def _nbytes(av) -> int:
    size = 1
    for d in av.shape:
        size *= int(d)
    return size * av.dtype.itemsize


def audit_site(site: PallasSite) -> List[str]:
    """Structural donation findings for one pallas site (empty = clean)."""
    findings: List[str] = []
    counts = ref_access_counts(site.kernel_jaxpr)

    for opi, outj in site.aliases.items():
        if opi >= len(site.in_avals) or outj >= len(site.out_avals):
            findings.append(f"{site.name}: alias ({opi}->{outj}) out of "
                            f"operand/result range")
            continue
        if site.in_avals[opi].shape != site.out_avals[outj].shape or \
                site.in_avals[opi].dtype != site.out_avals[outj].dtype:
            findings.append(
                f"{site.name}: alias ({opi}->{outj}) aval mismatch "
                f"{site.in_avals[opi]} vs {site.out_avals[outj]}")
        gets, _ = counts.get(site.root_of_operand(opi), (0, 0))
        if gets:
            findings.append(
                f"{site.name}: donated operand {opi} is read {gets}x in the "
                f"kernel body — donation invalidates its contents")

    # silent-copy sweep: unaliased full-size 1-D outputs with a donatable twin
    if site.num_inputs:
        buf_max = max(
            _nbytes(site.in_avals[i])
            for i in range(site.num_scalars,
                           site.num_scalars + site.num_inputs))
        aliased_ops = set(site.aliases)
        aliased_outs = set(site.aliases.values())
        for j, oav in enumerate(site.out_avals):
            if j in aliased_outs or len(oav.shape) != 1:
                continue
            if _nbytes(oav) < buf_max:
                continue
            gets, _ = counts.get(site.root_of_output(j), (0, 0))
            if gets:                # read-modify-write accumulator, not a
                continue            # ping-pong destination

            twin = any(
                site.in_avals[i].shape == oav.shape and
                site.in_avals[i].dtype == oav.dtype and i not in aliased_ops
                for i in range(site.num_scalars,
                               site.num_scalars + site.num_inputs))
            if twin:
                findings.append(
                    f"{site.name}: output {j} ({oav.dtype}{list(oav.shape)}) "
                    f"is a full-size buffer with an identically-shaped "
                    f"operand available but NO input_output_alias — the "
                    f"ping-pong buffer silently copies instead of aliasing")
    return findings


def check_donation(sites: List[PallasSite], decl: Dict[str, str],
                   params: Dict) -> List[str]:
    """Declared + structural donation audit over a trace's sites."""
    findings: List[str] = []
    expected = {k: int(expr.evaluate(f, params))
                for k, f in (decl or {}).items()}
    seen = {k: 0 for k in expected}
    for site in sites:
        findings.extend(audit_site(site))
        for kname, want in expected.items():
            if site.name == kname:
                seen[kname] += 1
                if len(site.aliases) != want:
                    findings.append(
                        f"{site.name}: expected {want} alias pair(s), "
                        f"found {len(site.aliases)}")
    for kname, n in seen.items():
        if n == 0:
            findings.append(
                f"declared donation kernel {kname!r} never appears in trace")
    return findings
