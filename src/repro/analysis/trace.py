"""Jaxpr-walking layer shared by every contract check.

Everything the analyzer proves is read off the traced program — never by
executing it.  This module turns a (Closed)Jaxpr into:

  * :class:`PallasSite` records — every ``pallas_call`` equation, with its
    control-flow context (inside a while body or not), grid, operand/result
    avals, scalar-prefetch split and ``input_output_aliases`` — the raw
    material for the census, donation, transfer and ref-hazard passes,
  * per-kernel ref access summaries (:func:`ref_access_counts`,
    :func:`ref_events`): every ``get``/``swap`` on a kernel operand ref, in
    program order, classified static vs dynamic by recovering the
    ``NDIndexer`` the Pallas tracer flattened into the equation.  Accesses
    inside sub-jaxprs (``pl.when`` conds, inner loops) are attributed to the
    outer kernel ref through an invar environment.
  * collective-primitive shapes (:func:`collective_link_bytes`) with the
    same wire weights as ``utils.hlo.collective_bytes`` — the jaxpr-level
    counterpart used where partitioned HLO is unavailable (AbstractMesh
    traces).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax


def _unwrap(jaxpr):
    return jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr


def _aval(var):
    av = var.aval
    return getattr(av, "inner_aval", av)


def _is_literal(x) -> bool:
    return isinstance(x, jax.core.Literal) or not hasattr(x, "aval")


@dataclass
class RefEvent:
    """One ``get``/``swap`` on a kernel operand ref, in program order."""
    kind: str                    # "get" | "swap"
    order: int                   # DFS program-order index within the kernel
    dynamic: bool                # any traced (non-static) index component
    scatter: bool                # advanced (array-valued) indexing
    indexer: Any = None          # recovered NDIndexer tuple, or None


@dataclass
class PallasSite:
    """One ``pallas_call`` equation with its analysis-relevant structure."""
    name: str                    # kernel function name
    src: str                     # full name_and_src_info string
    grid: Tuple[int, ...]
    in_while: bool               # inside any while-loop body
    num_scalars: int             # scalar-prefetch operands (index space head)
    num_inputs: int              # non-scalar inputs
    num_outputs: int
    in_avals: List[Any]          # ALL operand avals (scalars first)
    out_avals: List[Any]
    aliases: Dict[int, int]      # absolute operand index -> output index
    eqn: Any = field(repr=False, default=None)

    @property
    def kernel_jaxpr(self):
        return _unwrap(self.eqn.params["jaxpr"])

    def operand_aval(self, idx: int):
        return self.in_avals[idx]

    def root_of_operand(self, idx: int) -> int:
        """Kernel-invar index of operand ``idx`` (identity: scalars lead)."""
        return idx

    def root_of_output(self, j: int) -> int:
        return self.num_scalars + self.num_inputs + j

    def classify_root(self, root: int) -> Tuple[str, int]:
        if root < self.num_scalars:
            return ("scalar", root)
        if root < self.num_scalars + self.num_inputs:
            return ("input", root)               # == absolute operand index
        j = root - self.num_scalars - self.num_inputs
        if j < self.num_outputs:
            return ("output", j)
        return ("scratch", j - self.num_outputs)

    def block_mappings(self):
        return tuple(self.eqn.params["grid_mapping"].block_mappings)


def _sub_jaxprs_with_env(eqn):
    """Yield (sub_jaxpr, operand_list) pairs mapping sub invars to outer vars.

    The operand list aligns positionally with the sub-jaxpr's invars;
    entries may be ``None`` where no outer var corresponds (e.g. consts).
    Handles the primitives that appear inside Pallas kernel bodies: ``cond``
    (operands follow the predicate), ``while`` (cond consts, body consts,
    carry), ``scan``/``pjit``/``closed_call`` (1:1), with a zip fallback.
    """
    name = eqn.primitive.name
    params = eqn.params
    if name == "cond":
        ops = list(eqn.invars[1:])
        for br in params["branches"]:
            yield _unwrap(br), ops
        return
    if name == "while":
        cn, bn = params["cond_nconsts"], params["body_nconsts"]
        carry = list(eqn.invars[cn + bn:])
        yield _unwrap(params["cond_jaxpr"]), list(eqn.invars[:cn]) + carry
        yield _unwrap(params["body_jaxpr"]), \
            list(eqn.invars[cn:cn + bn]) + carry
        return
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if not hasattr(x, "eqns"):
                continue
            sub = _unwrap(x)          # ClosedJaxpr proxies .eqns, not .invars
            ops = list(eqn.invars)
            if len(sub.invars) != len(ops):
                ops = [None] * len(sub.invars)   # conservative: untracked
            yield sub, ops


def collect_pallas_sites(jaxpr, _in_while: bool = False) -> List[PallasSite]:
    """Every ``pallas_call`` site in trace order, tagged with while context."""
    jaxpr = _unwrap(jaxpr)
    out: List[PallasSite] = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            gm = eqn.params["grid_mapping"]
            nsi = str(eqn.params.get("name_and_src_info", ""))
            raw = eqn.params.get("input_output_aliases", ())
            out.append(PallasSite(
                name=nsi.split(" at ")[0] if nsi else "<pallas>",
                src=nsi,
                grid=tuple(int(g) for g in gm.grid),
                in_while=_in_while,
                num_scalars=int(gm.num_index_operands),
                num_inputs=int(gm.num_inputs),
                num_outputs=int(gm.num_outputs),
                in_avals=[_aval(v) for v in eqn.invars],
                out_avals=[_aval(v) for v in eqn.outvars],
                aliases={int(i): int(o) for i, o in raw},
                eqn=eqn,
            ))
            continue
        inside = _in_while or eqn.primitive.name == "while"
        for sub, _ in _sub_jaxprs_with_env(eqn):
            out.extend(collect_pallas_sites(sub, inside))
    return out


def _recover_indexers(eqn):
    """Unflatten the NDIndexer(s) of a get/swap eqn; None if unavailable."""
    tree = eqn.params.get("tree")
    if tree is None:
        return None
    skip = 1 if eqn.primitive.name == "get" else 2   # get: (ref,); swap: (ref, val)
    try:
        idx = jax.tree_util.tree_unflatten(tree, eqn.invars[skip:])
    except Exception:
        return None
    flat = idx if isinstance(idx, (tuple, list)) else (idx,)
    return tuple(x for x in flat if hasattr(x, "indices"))


def _indexer_dynamics(eqn, indexers) -> Tuple[bool, bool]:
    """(dynamic, scatter) classification of a get/swap's index arguments."""
    if indexers is not None:
        dynamic = scatter = False
        for nd in indexers:
            for comp in nd.indices:
                if hasattr(comp, "start"):            # Slice
                    if not _is_literal(comp.start):
                        dynamic = True
                elif _is_literal(comp):
                    continue
                else:                                  # traced index
                    dynamic = True
                    shape = getattr(_safe_aval(comp), "shape", ())
                    if shape:
                        scatter = True
            if getattr(nd, "int_indexer_shape", ()):
                scatter = True
        return dynamic, scatter
    skip = 1 if eqn.primitive.name == "get" else 2
    extra = eqn.invars[skip:]
    dyn = any(not _is_literal(v) for v in extra)
    scat = any(not _is_literal(v) and
               getattr(_safe_aval(v), "shape", ()) for v in extra)
    return dyn, scat


def _safe_aval(x):
    try:
        return x.aval
    except Exception:
        return None


def ref_events(kernel_jaxpr) -> Dict[int, List[RefEvent]]:
    """Program-ordered get/swap events per kernel operand-ref index.

    Events inside conditionals count unconditionally (a hazard behind a
    predicate is still a hazard); inner-jaxpr refs are mapped back to the
    outer kernel invars they alias via the invar environment.
    """
    kernel_jaxpr = _unwrap(kernel_jaxpr)
    events: Dict[int, List[RefEvent]] = {}
    counter = [0]

    def walk(j, env):
        for eqn in j.eqns:
            if eqn.primitive.name in ("get", "swap"):
                root = env.get(id(eqn.invars[0]))
                counter[0] += 1
                if root is not None:
                    indexers = _recover_indexers(eqn)
                    dyn, scat = _indexer_dynamics(eqn, indexers)
                    events.setdefault(root, []).append(RefEvent(
                        kind=eqn.primitive.name, order=counter[0],
                        dynamic=dyn, scatter=scat, indexer=indexers))
                continue
            for sub, ops in _sub_jaxprs_with_env(eqn):
                sub_env = {}
                for iv, ov in zip(sub.invars, ops):
                    if ov is not None and id(ov) in env:
                        sub_env[id(iv)] = env[id(ov)]
                if sub_env:
                    walk(sub, sub_env)

    walk(kernel_jaxpr,
         {id(v): i for i, v in enumerate(kernel_jaxpr.invars)})
    return events


def ref_access_counts(kernel_jaxpr) -> Dict[int, Tuple[int, int]]:
    """{operand-ref index: (num_gets, num_swaps)} for a kernel body."""
    out = {}
    for root, evs in ref_events(kernel_jaxpr).items():
        gets = sum(1 for e in evs if e.kind == "get")
        swaps = sum(1 for e in evs if e.kind == "swap")
        out[root] = (gets, swaps)
    return out


# ----- sort primitive census (jaxpr-level sort-free certification) ----------

def sort_primitive_count(jaxpr) -> int:
    """Recursive count of ``sort`` primitives (jnp.sort/argsort/lexsort),
    including inside Pallas kernel bodies and control-flow sub-jaxprs."""
    jaxpr = _unwrap(jaxpr)
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "sort":
            total += 1
        for sub, _ in _sub_jaxprs_with_env(eqn):
            total += sort_primitive_count(sub)
    return total


# ----- collective accounting at jaxpr level (AbstractMesh traces) -----------

# wire-byte weights mirror utils.hlo.collective_bytes: sizes are the
# per-device result bytes, P the exchange width.
def collective_link_bytes(jaxpr, num_devices: int):
    """(per-kind wire bytes, per-kind site counts) from jaxpr collectives.

    Shard-map / AbstractMesh traces never reach partitioned HLO on this
    container, so the link accounting reads the collective *primitives*
    instead: ``all_to_all`` / ``all_gather`` wire ``out·(P−1)/P``, ``psum``
    (all-reduce) ``2·out·(P−1)/P``, ``ppermute`` ``out`` — per site, counted
    once per trace site (cond-guarded retry attempts each count once, the
    executed-vs-nominal convention of the launch census).
    """
    p = max(int(num_devices), 1)
    frac = (p - 1) / p
    bytes_by: Dict[str, float] = {}
    counts: Dict[str, int] = {}

    def nbytes(var):
        av = _aval(var)
        size = 1
        for d in av.shape:
            size *= int(d)
        return size * av.dtype.itemsize

    def walk(j):
        j = _unwrap(j)
        for eqn in j.eqns:
            nm = eqn.primitive.name
            if nm in ("all_to_all", "all_gather"):
                wire = sum(nbytes(v) for v in eqn.outvars) * frac
            elif nm in ("psum", "psum2"):
                wire = 2 * sum(nbytes(v) for v in eqn.outvars) * frac
            elif nm in ("psum_scatter", "reduce_scatter"):
                wire = sum(nbytes(v) for v in eqn.invars) * p * frac
            elif nm == "ppermute":
                wire = float(sum(nbytes(v) for v in eqn.outvars))
            else:
                for sub, _ in _sub_jaxprs_with_env(eqn):
                    walk(sub)
                continue
            bytes_by[nm] = bytes_by.get(nm, 0.0) + wire
            counts[nm] = counts.get(nm, 0) + 1

    walk(jaxpr)
    bytes_by["total"] = sum(bytes_by.values())
    return bytes_by, counts
