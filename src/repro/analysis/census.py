"""Launch-census verification against symbolic contract formulas.

The structural headline of §4.3–§4.4 — ONE fused launch per counting pass,
one merge launch per round — is declared next to each engine as formulas in
(passes, rounds, classes, attempts, chunks) and verified here against the
actual trace: total ``pallas_call`` sites, per-while-body launch counts, and
optionally the batched fused-launch grid (⌈g_max/B⌉, the
``plan.pack_region_blocks`` contract).
"""
from __future__ import annotations

from typing import Dict, List

from repro.analysis import expr
from repro.analysis.trace import PallasSite
from repro.utils import hlo


def check_census(jaxpr, sites: List[PallasSite], decl: Dict,
                 params: Dict) -> List[str]:
    findings: List[str] = []
    got = hlo.launch_census(jaxpr)

    want_total = int(expr.evaluate(decl["launch_total"], params))
    if got["total"] != want_total:
        findings.append(
            f"launch total {got['total']} != declared "
            f"{decl['launch_total']!r} = {want_total}")

    want_while = [int(x)
                  for x in expr.evaluate(decl["while_body_launches"], params)]
    if list(got["while_bodies"]) != want_while:
        findings.append(
            f"while-body launches {got['while_bodies']} != declared "
            f"{decl['while_body_launches']!r} = {want_while}")

    # cross-check the site collector against utils.hlo (one impl per layer,
    # same count — a disagreement means the walker missed a context)
    if len(sites) != got["total"]:
        findings.append(
            f"site collector found {len(sites)} pallas sites but "
            f"utils.hlo counts {got['total']}")

    if "fused_grid" in decl:
        want_grid = int(expr.evaluate(decl["fused_grid"], params))
        fused = [s for s in sites if s.name == "_fused_pass_kernel"]
        if not fused:
            findings.append("fused_grid declared but no _fused_pass_kernel "
                            "site in trace")
        for s in fused:
            if s.grid != (want_grid,):
                findings.append(
                    f"{s.name}: grid {s.grid} != declared "
                    f"{decl['fused_grid']!r} = ({want_grid},)")
    return findings
