"""Static transfer accounting: derive bytes from operand shapes, diff tables.

The §4.3/§4.4 tables in ``kernels/__init__`` claim a hybrid sort moves
exactly ``(2·⌈k/d⌉+1)·n·b`` key bytes (+ ``2·⌈k/d⌉·n·v`` value bytes) and a
merge round ``2·buf·(b+v)``.  This pass re-derives those numbers from the
*trace*: for every declared sweep kernel, each full-length buffer operand
the kernel actually ``get``s is one read sweep and each full-length output
it ``swap``s (without reading — accumulators are read-modify-write and
excluded) is one write sweep; a while-body site multiplies by the nominal
pass count.  The derived total must equal the declared formula exactly — no
tolerance, the tables are closed-form.

Distributed link traffic is derived the same way from collective-primitive
result shapes (``trace.collective_link_bytes``) with the wire weights of
``utils.hlo.collective_bytes``, and diffed against the ICI table's formula.
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.analysis import expr
from repro.analysis.trace import PallasSite, ref_access_counts


def _size(av) -> int:
    size = 1
    for d in av.shape:
        size *= int(d)
    return size


def site_sweep_bytes(site: PallasSite, n_pad: int) -> Dict[str, List]:
    """Read/write sweep breakdown of one site over its n_pad-sized buffers.

    A buffer operand/output counts iff its total element count equals the
    ping-pong padded length ``n_pad`` (the fused kernel sees keys flat, the
    histogram prologue sees the same buffer tiled (T, kpb) — both count).
    Outputs that the kernel also reads (gets > 0) are accumulator tables
    (histogram/carry), not HBM sweeps, and are excluded.
    """
    counts = ref_access_counts(site.kernel_jaxpr)
    reads, writes = [], []
    for i in range(site.num_scalars, site.num_scalars + site.num_inputs):
        av = site.in_avals[i]
        gets, _ = counts.get(site.root_of_operand(i), (0, 0))
        if _size(av) == n_pad and gets > 0:
            reads.append((i, av))
    for j, av in enumerate(site.out_avals):
        gets, swaps = counts.get(site.root_of_output(j), (0, 0))
        if _size(av) == n_pad and swaps > 0 and gets == 0:
            writes.append((j, av))
    return {"reads": reads, "writes": writes}


def derive_hbm_bytes(sites: List[PallasSite], decl: Dict,
                     params: Dict) -> Dict:
    """Sum sweep bytes over the declared sweep kernels of a trace.

    While-body sites multiply by the nominal ``passes`` parameter — the
    trace contains the loop body once, the schedule runs it ``⌈k/d⌉`` times
    (the same executed-vs-nominal convention as the launch census; adaptive
    elision only ever lowers the real traffic below this bound).
    """
    n_pad = int(params["n_pad"])
    passes = int(params.get("passes", 1))
    sweep_kernels = set(decl["sweep_kernels"])
    total = 0
    per_site = []
    for site in sites:
        if site.name not in sweep_kernels:
            continue
        sw = site_sweep_bytes(site, n_pad)
        mult = passes if site.in_while else 1
        site_bytes = mult * sum(
            _size(av) * av.dtype.itemsize
            for _, av in sw["reads"] + sw["writes"])
        total += site_bytes
        per_site.append({
            "kernel": site.name, "in_while": site.in_while, "mult": mult,
            "reads": len(sw["reads"]), "writes": len(sw["writes"]),
            "bytes": site_bytes})
    return {"total": total, "sites": per_site}


def check_hbm_bytes(sites: List[PallasSite], decl: Dict,
                    params: Dict) -> List[str]:
    derived = derive_hbm_bytes(sites, decl, params)
    want = expr.evaluate(decl["bytes"], params)
    findings = []
    if derived["total"] != int(want):
        findings.append(
            f"derived HBM sweep bytes {derived['total']} != declared "
            f"{decl['bytes']!r} = {int(want)} "
            f"(sites: {derived['sites']})")
    if not derived["sites"]:
        findings.append("no sweep-kernel sites found for transfer accounting")
    return findings


def check_link_bytes(jaxpr_collectives, decl: Dict, params: Dict) -> List[str]:
    """Diff jaxpr-derived wire bytes/counts against the ICI table formulas.

    ``jaxpr_collectives`` is the (bytes_by_kind, counts_by_kind) pair from
    ``trace.collective_link_bytes``.
    """
    bytes_by, counts = jaxpr_collectives
    findings: List[str] = []
    for kind, formula in decl.get("collective_counts", {}).items():
        want = int(expr.evaluate(formula, params))
        got = counts.get(kind, 0)
        if got != want:
            findings.append(
                f"{kind} site count {got} != declared {formula!r} = {want}")
    if "link_bytes" in decl:
        want = float(expr.evaluate(decl["link_bytes"], params))
        got = bytes_by.get("total", 0.0)
        if not math.isclose(got, want, rel_tol=1e-9, abs_tol=0.5):
            findings.append(
                f"derived wire bytes {got} != declared formula = {want} "
                f"(by kind: { {k: v for k, v in bytes_by.items()} })")
    return findings
