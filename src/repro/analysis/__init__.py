"""Static analysis of the sort engines' kernel contracts.

Nothing in this package executes device code: every property is read off
the traced jaxpr (``jax.make_jaxpr``), the Pallas kernel bodies inside it,
descriptor-table instances from the host planners, or the source AST.

  ``trace``      jaxpr walking: pallas sites, ref events, collectives
  ``expr``       restricted evaluator for the declared symbolic formulas
  ``census``     launch-census verification (one launch per counting pass)
  ``donation``   input_output_aliases audit (no silent ping-pong copies)
  ``transfer``   HBM sweep / ICI wire bytes derived from operand shapes
  ``refhazard``  scatter disjointness, RAW hazards, slice-extent bounds
  ``lint``       AST rules (no jnp.sort in engines, no global PRNG, ...)
  ``contracts``  the registry binding declarations to trace recipes

``python -m repro.analysis`` runs the whole sweep (see ``__main__``).
"""
from repro.analysis.contracts import (CONTRACTS, REGISTRY, Contract,
                                      ContractReport, dist_params,
                                      expected_census, hybrid_params,
                                      lsd_params, merge_params, run_all,
                                      run_contract, spp_params, table_checks)
from repro.analysis.lint import LintFinding, lint_source, run_lint

__all__ = [
    "CONTRACTS", "REGISTRY", "Contract", "ContractReport",
    "dist_params", "expected_census", "hybrid_params", "lsd_params",
    "merge_params", "spp_params",
    "run_all", "run_contract", "table_checks",
    "LintFinding", "lint_source", "run_lint",
]
