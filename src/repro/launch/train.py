"""Production training driver: ``python -m repro.launch.train --arch <id>``.

On real hardware this launches the sharded train loop over the production
mesh; on this CPU container it runs the same code path on whatever devices
exist (use --smoke for the reduced config).  Demonstrates the full stack:
config -> sharded params -> fault-tolerant trainer -> checkpoints.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLMData
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    seq = args.seq_len or (128 if args.smoke else 4096)
    gb = args.global_batch or (8 if args.smoke else 256)

    data = SyntheticLMData(vocab=cfg.vocab, seq_len=seq, global_batch=gb,
                           num_patches=cfg.num_patches
                           if cfg.frontend == "vision_patches" else 0,
                           d_model=cfg.d_model)
    tr = Trainer(cfg, data, f"{args.ckpt_dir}/{cfg.name}",
                 ckpt_every=args.ckpt_every, base_lr=args.lr,
                 total_steps=args.steps)
    state = tr.init_or_resume(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, seq={seq}, batch={gb}, "
          f"devices={jax.device_count()}")
    tr.run(state, args.steps)


if __name__ == "__main__":
    main()
