"""Production mesh definition (multi-pod dry-run contract).

A FUNCTION, not a module constant: importing this module never touches jax
device state, so smoke tests keep seeing the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2, 16, 16) = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes that carry the batch dimension (pod composes with data)."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def data_shards(mesh) -> int:
    import math
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


def model_shards(mesh) -> int:
    return mesh.shape["model"]
