import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh) cell.

For each cell this driver:
  1. builds ShapeDtypeStruct stand-ins for params/optimizer/batch/cache
     (zero allocation — the full configs exist only as shapes),
  2. jits the real step (train_step / prefill serve_step / decode serve_step)
     with explicit in_shardings from launch/sharding.py,
  3. ``.lower().compile()`` against the production mesh (16x16 single-pod and
     2x16x16 multi-pod),
  4. prints ``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs,
     bytes) and parses the partitioned HLO for per-chip collective wire bytes,
  5. writes a JSON artifact consumed by benchmarks/roofline.py and
     EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch internlm2_1_8b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config, SHAPES, shapes_for
from repro.launch.mesh import make_production_mesh, data_axes, data_shards
from repro.launch import sharding as shd
from repro.models import init_params, init_cache, prefill, decode_step
from repro.optim import get_optimizer
from repro.train import make_train_step, TrainState
from repro.utils.hlo import collective_bytes, collective_counts
from repro.utils.roofline import Roofline, model_flops


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg, shape_cfg, mesh):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    if shape_cfg.kind in ("train", "prefill"):
        text = s - (cfg.num_patches if cfg.frontend == "vision_patches" else 0)
        batch = {"tokens": jax.ShapeDtypeStruct((b, text), jnp.int32)}
        if cfg.frontend == "vision_patches":
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.d_model), jnp.float32)
        return batch
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {"token": token, "cache": cache}


def _prepare(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, dispatch_groups=data_shards(mesh))
    shape_cfg = SHAPES[shape_name]
    return cfg, shape_cfg


def _clip_layers(cfg, n: int):
    """Same config with n UNROLLED layers (for the two-point cost fit —
    XLA's cost model skips while-loop bodies, so the fit lowerings unroll)."""
    globals_ = tuple(g for g in cfg.global_attn_layers if g < n)
    return dataclasses.replace(cfg, n_layers=n, global_attn_layers=globals_,
                               scan_unroll=True)


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               step_override: str = None, cfg_override=None,
               fit_layers: bool = True):
    """Lower + compile one cell; returns the artifact dict.

    XLA's cost_analysis counts a while-loop (scan) body ONCE regardless of the
    trip count, so FLOPs/bytes/collectives are extrapolated linearly from two
    extra lowerings at n_layers=1 and n_layers=2 (cost(L) = a + b*L);
    memory_analysis comes from the real-depth program.
    """
    cfg, shape_cfg = _prepare(arch, shape_name, mesh)
    if cfg_override:
        cfg = cfg_override(cfg)
    step_kind = step_override or ("train" if shape_cfg.kind == "train" else
                                  "prefill" if shape_cfg.kind == "prefill"
                                  else "decode")
    chips = mesh.devices.size
    dp = data_axes(mesh)

    def _lower(c):
        params_shape = jax.eval_shape(
            lambda: init_params(c, jax.random.PRNGKey(0)))
        pshard = shd.param_shardings(params_shape, c, mesh)
        if step_kind == "train":
            opt = get_optimizer(c.optimizer)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            oshard = shd.param_shardings(opt_shape, c, mesh)
            state_sds = TrainState(params_shape, opt_shape,
                                   jax.ShapeDtypeStruct((), jnp.int32))
            state_shard = TrainState(pshard, oshard, NamedSharding(mesh, P()))
            batch_sds = input_specs(c, shape_cfg, mesh)
            bshard = shd.to_shardings(shd.batch_specs(c, mesh, shape_cfg), mesh)

            from repro.models import loss_fn
            from repro.optim import clip_by_global_norm, cosine_schedule
            lr_fn = cosine_schedule(3e-4, 100, 10_000)

            def step_fn(state, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(p, c, batch, remat=c.remat),
                    has_aux=True)(state.params)
                grads, gnorm = clip_by_global_norm(grads, 1.0)
                new_p, new_o = opt.update(grads, state.opt_state, state.params,
                                          lr_fn(state.step))
                return TrainState(new_p, new_o, state.step + 1), loss

            fn = jax.jit(step_fn, in_shardings=(state_shard, bshard),
                         donate_argnums=(0,))
            return fn.lower(state_sds, batch_sds)
        if step_kind == "prefill":
            batch_sds = input_specs(c, shape_cfg, mesh)
            bshard = shd.to_shardings(shd.batch_specs(c, mesh, shape_cfg), mesh)
            cshard = shd.to_shardings(
                shd.cache_specs(c, mesh, shape_cfg.global_batch,
                                shape_cfg.seq_len), mesh)
            v_ok = c.padded_vocab % mesh.shape["model"] == 0
            lshard = NamedSharding(mesh, P(
                dp if shape_cfg.global_batch % data_shards(mesh) == 0 else None,
                None, "model" if v_ok else None))

            def serve_prefill(params, batch):
                return prefill(params, c, batch, remat=c.remat)

            fn = jax.jit(serve_prefill, in_shardings=(pshard, bshard),
                         out_shardings=(lshard, cshard))
            return fn.lower(params_shape, batch_sds)
        # decode
        spec = input_specs(c, shape_cfg, mesh)
        cshard = shd.to_shardings(
            shd.cache_specs(c, mesh, shape_cfg.global_batch,
                            shape_cfg.seq_len), mesh)
        tshard = NamedSharding(
            mesh, P(dp, None) if shape_cfg.global_batch % data_shards(mesh) == 0
            else P())

        def serve_decode(params, token, cache):
            return decode_step(params, c, token, cache)

        fn = jax.jit(serve_decode, in_shardings=(pshard, tshard, cshard),
                     donate_argnums=(2,))
        return fn.lower(params_shape, spec["token"], spec["cache"])

    def _costs(compiled_exe):
        ca_ = compiled_exe.cost_analysis()
        if isinstance(ca_, list):
            ca_ = ca_[0]
        hlo_ = compiled_exe.as_text()
        coll_ = collective_bytes(hlo_, chips)
        return (float(ca_.get("flops", 0.0)),
                float(ca_.get("bytes accessed", 0.0)),
                float(coll_.get("total", 0.0)), coll_, collective_counts(hlo_))

    t0 = time.time()
    with jax.set_mesh(mesh):          # binds in-model sharding constraints
        lowered = _lower(cfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    flops0, bytes0, cbytes0, coll, counts = _costs(compiled)

    if fit_layers and cfg.n_layers > 2:
        # two-point fit: cost(L) = a + b*L (scan body counted once by XLA).
        # Slopes are clamped at 0 — GSPMD occasionally picks different
        # strategies for the two small lowers (flagged as degenerate).
        with jax.set_mesh(mesh):
            f1, b1, c1, _, _ = _costs(_lower(_clip_layers(cfg, 1)).compile())
            f2, b2, c2, _, _ = _costs(_lower(_clip_layers(cfg, 2)).compile())
        l = cfg.n_layers
        flops = max(f1 + max(f2 - f1, 0.0) * (l - 1), flops0)
        hbytes = max(b1 + max(b2 - b1, 0.0) * (l - 1), bytes0)
        cbytes = max(c1 + max(c2 - c1, 0.0) * (l - 1), cbytes0)
        fit = {"flops_l1": f1, "flops_l2": f2, "raw_flops": flops0,
               "raw_bytes": bytes0, "raw_coll": cbytes0,
               "degenerate": bool(f2 < f1 or b2 < b1 or c2 < c1)}
    else:
        flops, hbytes, cbytes = flops0, bytes0, cbytes0
        fit = {}

    mem_total = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
                 mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rl = Roofline(
        arch=arch, shape=shape_name, step=step_kind, mesh=mesh_name,
        chips=chips,
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbytes,
        coll_bytes_per_chip=cbytes,
        model_flops_global=model_flops(cfg, shape_cfg),
        mem_per_chip=float(max(mem_total, 0)),
    )
    art = {
        **rl.row(),
        "lower_s": t_lower, "compile_s": t_compile, "layer_fit": fit,
        "collective_bytes": coll, "collective_counts": counts,
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "ok": True,
    }
    print(f"[dryrun] {mesh_name}/{arch}/{shape_name}/{step_kind}: "
          f"mem={art['mem_per_chip_gib']:.2f} GiB/chip "
          f"t_comp={rl.t_compute*1e3:.2f}ms t_mem={rl.t_memory*1e3:.2f}ms "
          f"t_coll={rl.t_collective*1e3:.2f}ms -> {rl.bottleneck} "
          f"(compile {t_compile:.1f}s)")
    print(f"[dryrun]   memory_analysis: {mem}")
    return art


def run_cells(archs, shapes, meshes, out_dir, cfg_override=None):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        for arch in archs:
            cfg = get_config(arch)
            wanted = shapes or list(SHAPES)       # all 4 => 40 cells/mesh
            for shape_name in wanted:
                if (shape_name == "long_500k"
                        and not cfg.supports_long_context):
                    results.append({"arch": arch, "shape": shape_name,
                                    "mesh": mesh_name, "ok": False,
                                    "skipped": "full-attention arch: 524k dense"
                                               " KV decode is the quadratic"
                                               " regime this shape excludes"})
                    continue
                tag = f"{mesh_name}_{arch}_{shape_name}"
                try:
                    art = lower_cell(arch, shape_name, mesh, mesh_name,
                                     cfg_override=cfg_override)
                except Exception as e:   # a failure here is a bug — record it
                    traceback.print_exc()
                    art = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                           "ok": False, "error": f"{type(e).__name__}: {e}"}
                results.append(art)
                with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                    json.dump(art, f, indent=2, default=str)
    with open(os.path.join(out_dir, "summary.json"), "w") as f:
        json.dump(results, f, indent=2, default=str)
    bad = [r for r in results if not r.get("ok") and "skipped" not in r]
    print(f"[dryrun] {len(results)} cells, {len(bad)} failures")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="beyond-paper config: flash attention everywhere")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = None if (args.all or not args.shape) else [args.shape]
    override = None
    if args.optimized:
        override = lambda c: dataclasses.replace(c, attention_impl="flash")
    run_cells(archs, shapes, meshes, args.out, cfg_override=override)


if __name__ == "__main__":
    main()
