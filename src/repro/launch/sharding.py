"""Name-based sharding rules: DP over (pod, data), TP/EP over model, FSDP
storage sharding over data for the large architectures.

Rules are *divisibility-guarded*: a dimension is sharded only when it divides
the axis size (e.g. musicgen's 24 heads don't divide the 16-way model axis ->
attention weights replicate, the FFN still shards).  Everything is expressed
over axis NAMES, so the same rules re-apply on any mesh — the elasticity
contract.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


def _norm_axis(axis):
    """Collapse single-element axis tuples to the bare name.

    ``data_axes(mesh)`` returns a tuple so pod composes with data, but a
    one-axis mesh partition must read ``P(None, 'data', None)`` — the
    canonical spec every consumer (and ``PartitionSpec`` equality) expects —
    not ``P(None, ('data',), None)``.  Multi-axis tuples pass through.
    """
    if isinstance(axis, tuple):
        if len(axis) == 1:
            return axis[0]
        return axis if axis else None
    return axis


def _div(n: int, mesh, axis) -> bool:
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return n % size == 0 and n > 0


def param_spec(path: str, shape, cfg, mesh) -> P:
    """PartitionSpec for one parameter leaf (path from tree_flatten_with_path).

    Stacked layer params carry a leading L dim (never sharded).
    """
    dp = data_axes(mesh)
    stacked = "layers" in path
    dims = list(shape[1:] if stacked else shape)

    def out(*spec):
        spec = [_norm_axis(s) for s in spec] + [None] * (len(dims) - len(spec))
        return P(*( [None] + spec if stacked else spec ))

    fsdp = cfg.fsdp_params

    if "embed" in path or "lm_head" in path:
        v_dim = 0 if "embed" in path else 1
        if len(dims) < 2:                  # factored optimizer state (vr/vc)
            return out()
        if _div(dims[v_dim], mesh, "model"):
            return out(*(("model", None) if v_dim == 0 else (None, "model")))
        return out()

    if "router" in path:
        return out()
    if "w_gate" in path or "w_up" in path or "w_down" in path:
        if len(dims) == 3:                        # MoE experts (E, d, f)/(E, f, d)
            spec = ["model" if _div(dims[0], mesh, "model") else None, None, None]
            if fsdp and _div(dims[1], mesh, dp):
                spec[1] = dp
            return out(*spec)
        if len(dims) != 2:                        # factored state
            return out()
        # dense FFN (d, f) / (f, d)
        f_dim = 1 if "down" not in path else 0
        spec = [None, None]
        if _div(dims[f_dim], mesh, "model"):
            spec[f_dim] = "model"
        if fsdp and _div(dims[1 - f_dim], mesh, dp):
            spec[1 - f_dim] = dp
        return out(*spec)

    if len(dims) < 2:                             # vectors / factored states
        return out()
    if any(k in path for k in ("wq", "wk", "wv")):
        heads = cfg.n_heads_padded if "wq" in path else cfg.n_kv_padded
        if heads and _div(heads, mesh, "model"):
            return out(None, "model")
        if fsdp and _div(dims[0], mesh, dp):
            return out(dp, None)
        return out()
    if "wo" in path:
        if cfg.n_heads and _div(cfg.n_heads_padded, mesh, "model"):
            return out("model", None)
        if fsdp and _div(dims[1], mesh, dp):
            return out(None, dp)
        return out()

    if "in_proj" in path:                          # ssm (d, 2di+2n+h)
        return out(None, "model") if _div(dims[1], mesh, "model") else out()
    if "out_proj" in path:                         # ssm (di, d)
        return out("model", None) if _div(dims[0], mesh, "model") else out()

    return out()                                   # norms, scalars, conv, A/D


def _spec_like(tree, cfg, mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        shp = jnp.shape(leaf)
        spec = param_spec(pstr, shp, cfg, mesh)
        if len(spec) > len(shp):                   # scalar/odd-rank state leaf
            spec = P()
        # rank/divisibility sanity: fall back to replication when mismatched
        ok = len(spec) <= len(shp)
        if ok:
            for dim, ax in zip(shp, tuple(spec) + (None,) * len(shp)):
                if ax is None:
                    continue
                if not _div(dim, mesh, ax if isinstance(ax, tuple) else ax):
                    ok = False
                    break
        specs.append(spec if ok else P())
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_shape, cfg, mesh):
    """NamedShardings for a params (or optimizer-state) pytree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        _spec_like(params_shape, cfg, mesh))


def batch_specs(cfg, mesh, shape_cfg) -> Any:
    dp = data_axes(mesh)
    b = shape_cfg.global_batch
    dpn = _norm_axis(dp)
    tok = P(dpn, None) if _div(b, mesh, dp) else P()
    out = {"tokens": tok}
    if cfg.frontend == "vision_patches":
        out["patches"] = P(dpn, None, None) if _div(b, mesh, dp) else P()
    return out


def cache_specs(cfg, mesh, batch: int, max_len: int):
    """DecodeCache specs: batch over DP when divisible, else sequence; KV heads
    over model when divisible, else sequence over model too (flash-decode
    style partial-KV layout)."""
    dp = data_axes(mesh)
    b_ok = _div(batch, mesh, dp)
    kv_ok = cfg.n_kv_heads and _div(cfg.n_kv_padded, mesh, "model")
    kv_k = kv_v = ssm_state = ssm_conv = None
    if cfg.has_attention:
        bspec = _norm_axis(dp) if b_ok else None
        hspec = "model" if kv_ok else None
        # sequence picks up every axis not used by batch/heads (flash-decode
        # partial-KV layout: each model shard holds a slice of history)
        seq_axes = tuple(a for ok, axes in ((b_ok, dp), (kv_ok, ("model",)))
                         if not ok for a in axes)
        sspec = (_norm_axis(seq_axes)
                 if seq_axes and _div(max_len, mesh, seq_axes) else None)
        kv_k = kv_v = P(None, bspec, sspec, hspec, None)
    if cfg.has_ssm:
        h_ok = _div(cfg.ssm_heads, mesh, "model")
        bs = _norm_axis(dp) if b_ok else None
        ssm_state = P(None, bs, "model" if h_ok else None, None, None)
        ssm_conv = P(None, bs, None, None)
    from repro.models import DecodeCache
    return DecodeCache(kv_k, kv_v, ssm_state, ssm_conv, P())


def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)
