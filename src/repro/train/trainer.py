"""Fault-tolerant training loop.

Large-scale posture (what survives a node failure at 1000+ nodes):
  * restart-exact data (batch = f(seed, step) — nothing to persist),
  * async checkpoints every N steps with atomic publish + hash verification,
  * resume = restore(latest) and continue at step+1 — tested by killing the
    loop mid-run (tests/test_train_loop.py),
  * synchronous SPMD steps: straggler mitigation comes from deterministic,
    balanced work assignment (no parameter-server tail) and, at the input
    layer, from sort-based length bucketing; preemption handling is
    checkpoint/restart,
  * elastic scaling: shardings are expressed over mesh *axis names*; on
    restart with a different device count the same rules re-apply (the mesh
    is rebuilt from the live device set).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.optim import get_optimizer, clip_by_global_norm, cosine_schedule
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def make_train_step(cfg, optimizer_name: Optional[str] = None,
                    base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, max_grad_norm: float = 1.0,
                    donate: bool = True, microbatches: int = 1):
    """Build the jitted train step: grad(loss) -> clip -> schedule -> update.

    ``microbatches > 1`` splits the batch along its leading axis and
    accumulates gradients with a lax.scan — peak activation memory drops by
    the microbatch factor while the math stays identical (mean of per-slice
    gradients == full-batch gradient for a mean loss; asserted in tests).
    """
    opt = get_optimizer(optimizer_name or cfg.optimizer)
    lr_fn = cosine_schedule(base_lr, warmup, total_steps)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=cfg.remat), has_aux=True
        )(params)

    def step_fn(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if microbatches > 1:
            sliced = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc_fn(carry, mb):
                (loss_a, grads_a) = carry
                (loss, metrics), grads = grads_of(state.params, mb)
                return (loss_a + loss / microbatches,
                        jax.tree.map(lambda a, g: a + g / microbatches,
                                     grads_a, grads)), metrics

            zeros = jax.tree.map(jnp.zeros_like, state.params)
            (loss, grads), mstack = jax.lax.scan(
                acc_fn, (jnp.float32(0.0), zeros), sliced)
            metrics = jax.tree.map(lambda m: m[-1], mstack)
        else:
            (loss, metrics), grads = grads_of(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_fn(state.step)
        new_params, new_opt = opt.update(grads, state.opt_state, state.params, lr)
        out = TrainState(new_params, new_opt, state.step + 1)
        return out, {"loss": loss, "grad_norm": gnorm, "lr": lr, **metrics}

    return opt, (jax.jit(step_fn, donate_argnums=(0,)) if donate
                 else jax.jit(step_fn))


@dataclasses.dataclass
class Trainer:
    cfg: Any
    data: Any                              # .batch(step) -> dict
    ckpt_dir: str
    ckpt_every: int = 50
    log_every: int = 10
    base_lr: float = 3e-4
    total_steps: int = 1000

    def init_or_resume(self, key) -> TrainState:
        from repro.models import init_params
        params = init_params(self.cfg, key)
        opt, self._step_fn = make_train_step(
            self.cfg, base_lr=self.base_lr, total_steps=self.total_steps)
        state = TrainState(params, opt.init(params), jnp.int32(0))
        last = latest_step(self.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(self.ckpt_dir, last, state)
            print(f"[trainer] resumed from step {last}")
        self._ckpt = AsyncCheckpointer(self.ckpt_dir)
        return state

    def run(self, state: TrainState, num_steps: int,
            on_step: Optional[Callable] = None) -> TrainState:
        t0 = time.time()
        start = int(state.step)
        for s in range(start, start + num_steps):
            batch = self.data.batch(s)
            state, metrics = self._step_fn(state, batch)
            if on_step is not None:
                on_step(s, state, metrics)
            if (s + 1) % self.log_every == 0:
                dt = (time.time() - t0) / (s - start + 1)
                print(f"[trainer] step {s+1} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms/step")
            if (s + 1) % self.ckpt_every == 0:
                self._ckpt.save(s + 1, state)
        self._ckpt.wait()
        return state
