"""Pallas TPU kernel: block-descriptor-driven histogram via scalar prefetch.

Paper §4.2: rather than one kernel launch per bucket, the GPU version launches
a *constant* number of kernels per pass and lets each thread block read its
{k_offs, k_count, b_id, b_offs} assignment from device memory.  The TPU
analogue is Pallas' scalar prefetch: the grid is the static block upper bound
(model I4) and the BlockSpec ``index_map`` *reads the assignment table* to
decide which input tile each grid step processes — data-dependent work
assignment with a single compiled kernel.

The general block-descriptor *generation* (segments plus done gaps, carry
resets, copy-through flags) lives in ``core.plan.make_region_blocks``, which
feeds ``kernels.fused`` — this module keeps the scalar-prefetch launch
pattern itself as the minimal tested exemplar.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _assigned_hist_kernel(tile_idx_ref, valid_ref, keys_ref, hist_ref, *,
                          shift: int, width: int):
    g = pl.program_id(0)
    r = 1 << width
    keys = keys_ref[...]                                  # the assigned tile
    digit = ((keys >> jnp.array(shift, keys.dtype)) &
             jnp.array(r - 1, keys.dtype)).astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[1], r), 1)
    onehot = (digit.reshape(-1, 1) == iota).astype(jnp.int32)
    ones = jnp.ones((1, keys.shape[1]), jnp.int32)
    h = jax.lax.dot_general(ones, onehot, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.int32)
    hist_ref[...] = h * valid_ref[g]                      # masked: padding rows of
    # the static-bound grid (I4) write zeros instead of branching


@functools.partial(jax.jit, static_argnames=("shift", "width", "interpret"))
def assigned_histogram(keys: jnp.ndarray, tile_idx: jnp.ndarray,
                       valid: jnp.ndarray, shift: int, width: int,
                       interpret: bool = True) -> jnp.ndarray:
    """Histogram of data-dependent tile assignments.

    keys: (T, KPB); tile_idx: (G,) int32 — which tile grid step g reads
    (the paper's k_offs in block units); valid: (G,) int32 {0,1}.
    Returns (G, 2^width) histograms, zero rows where invalid.
    """
    t, kpb = keys.shape
    g = tile_idx.shape[0]
    r = 1 << width
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, kpb), lambda i, idx, val: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, r), lambda i, idx, val: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_assigned_hist_kernel, shift=shift, width=width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, r), jnp.int32),
        interpret=interpret,
    )(tile_idx, valid, keys)
