"""Pallas TPU kernel: block-descriptor-driven histogram via scalar prefetch.

Paper §4.2: rather than one kernel launch per bucket, the GPU version launches
a *constant* number of kernels per pass and lets each thread block read its
{k_offs, k_count, b_id, b_offs} assignment from device memory.  The TPU
analogue is Pallas' scalar prefetch: the grid is the static block upper bound
(model I4) and the BlockSpec ``index_map`` *reads the assignment table* to
decide which input tile each grid step processes — data-dependent work
assignment with a single compiled kernel.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


class BlockAssignment(NamedTuple):
    """The paper's per-block descriptor table (M4), statically sized by I4.

    One row per grid step g of the constant-size kernel launch:
      seg_idx     — which (active) segment block g belongs to; ``a_max`` when
                    g is beyond the pass's real block count,
      key_offset  — absolute offset of the block's first key (the paper's
                    k_offs; b_offs is recovered as seg_base[seg_idx]),
      blk_in_seg  — block index within its segment (drives the in-segment
                    histogram carry),
      first_block — g-index of the segment's first block,
      valid       — bool, False on the static-bound padding rows.
    """
    seg_idx: jnp.ndarray
    key_offset: jnp.ndarray
    blk_in_seg: jnp.ndarray
    first_block: jnp.ndarray
    valid: jnp.ndarray


def make_block_assignments(seg_base: jnp.ndarray, seg_size: jnp.ndarray,
                           kpb: int, g_max: int) -> BlockAssignment:
    """Generate block descriptors for all segments at once (§4.2).

    ``seg_base``/``seg_size`` are (A,) int32 starts and lengths; segments may
    be empty (size 0 rows of the static table get no blocks).  Segment i
    contributes ceil(seg_size[i] / kpb) consecutive blocks; ``g_max`` is the
    static upper bound (model I4: n // kpb + max_active + 1).
    """
    a_max = seg_base.shape[0]
    nblk = (seg_size + kpb - 1) // kpb                       # (A,) blocks per seg
    blk_excl = jnp.cumsum(nblk) - nblk                       # first block of seg
    total = blk_excl[-1] + nblk[-1]

    # ownership via marks + prefix sum: mark each non-empty segment's first
    # block, count marks up to g, and map the count back through the list of
    # non-empty segments (empty rows never own a block).
    marks = jnp.zeros((g_max,), jnp.int32).at[
        jnp.where(nblk > 0, blk_excl, g_max)].add(1, mode="drop")
    seg_ord = jnp.cumsum(marks) - 1                          # rank among non-empty
    nonempty = jnp.nonzero(nblk > 0, size=a_max, fill_value=a_max)[0]
    g = jnp.arange(g_max, dtype=jnp.int32)
    valid = g < total
    seg_idx = jnp.where(
        valid, nonempty[jnp.clip(seg_ord, 0, a_max - 1)], a_max).astype(jnp.int32)
    seg_safe = jnp.clip(seg_idx, 0, a_max - 1)
    first_block = blk_excl[seg_safe].astype(jnp.int32)
    blk_in_seg = jnp.where(valid, g - first_block, 0)
    key_offset = jnp.where(valid, seg_base[seg_safe] + blk_in_seg * kpb, 0)
    return BlockAssignment(seg_idx, key_offset.astype(jnp.int32),
                           blk_in_seg.astype(jnp.int32), first_block, valid)


def _assigned_hist_kernel(tile_idx_ref, valid_ref, keys_ref, hist_ref, *,
                          shift: int, width: int):
    g = pl.program_id(0)
    r = 1 << width
    keys = keys_ref[...]                                  # the assigned tile
    digit = ((keys >> jnp.array(shift, keys.dtype)) &
             jnp.array(r - 1, keys.dtype)).astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[1], r), 1)
    onehot = (digit.reshape(-1, 1) == iota).astype(jnp.int32)
    ones = jnp.ones((1, keys.shape[1]), jnp.int32)
    h = jax.lax.dot_general(ones, onehot, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.int32)
    hist_ref[...] = h * valid_ref[g]                      # masked: padding rows of
    # the static-bound grid (I4) write zeros instead of branching


@functools.partial(jax.jit, static_argnames=("shift", "width", "interpret"))
def assigned_histogram(keys: jnp.ndarray, tile_idx: jnp.ndarray,
                       valid: jnp.ndarray, shift: int, width: int,
                       interpret: bool = True) -> jnp.ndarray:
    """Histogram of data-dependent tile assignments.

    keys: (T, KPB); tile_idx: (G,) int32 — which tile grid step g reads
    (the paper's k_offs in block units); valid: (G,) int32 {0,1}.
    Returns (G, 2^width) histograms, zero rows where invalid.
    """
    t, kpb = keys.shape
    g = tile_idx.shape[0]
    r = 1 << width
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, kpb), lambda i, idx, val: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, r), lambda i, idx, val: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_assigned_hist_kernel, shift=shift, width=width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, r), jnp.int32),
        interpret=interpret,
    )(tile_idx, valid, keys)
