"""Pallas TPU kernel: per-tile radix histogram via one-hot MXU contraction.

This is the TPU-native replacement for the paper's shared-memory-atomic
histogram (§4.3).  A GPU thread block increments 256 shared counters with
atomicAdd — throughput collapses for skewed inputs because all lanes hit one
counter (paper Fig. 2, "atomics only").  On TPU we instead form the one-hot
matrix of the tile's digits and contract it with a ones vector on the MXU:

    H = 1_{1 x KPB} . onehot(digit)_{KPB x r}

The contraction's cost is *independent of the digit distribution* — the
skew-robustness the paper gets from its thread-reduction trick (Fig. 2,
"thread reduction & atomics") falls out structurally.

Tiling: keys are viewed as (T, KPB); each grid step owns one tile in VMEM
(KPB * 4B = 32 KiB for the default KPB=8192 — well under the ~16 MiB VMEM
budget) and writes one (1, r) histogram row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(keys_ref, hist_ref, *, shift: int, width: int):
    r = 1 << width
    keys = keys_ref[...]                                   # (1, KPB) uint
    digit = ((keys >> jnp.array(shift, keys.dtype)) &
             jnp.array(r - 1, keys.dtype)).astype(jnp.int32)
    # one-hot in int32; contract over the key axis on the MXU
    iota = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[1], r), 1)
    onehot = (digit.reshape(-1, 1) == iota).astype(jnp.int32)   # (KPB, r)
    ones = jnp.ones((1, keys.shape[1]), jnp.int32)
    hist_ref[...] = jax.lax.dot_general(
        ones, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)                   # (1, r)


@functools.partial(jax.jit, static_argnames=("shift", "width", "interpret"))
def radix_histogram(keys: jnp.ndarray, shift: int, width: int,
                    interpret: bool = True) -> jnp.ndarray:
    """(T, KPB) uint keys -> (T, 2^width) int32 per-tile histograms."""
    t, kpb = keys.shape
    r = 1 << width
    return pl.pallas_call(
        functools.partial(_hist_kernel, shift=shift, width=width),
        grid=(t,),
        in_specs=[pl.BlockSpec((1, kpb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, r), jnp.int32),
        interpret=interpret,
    )(keys)
