"""Pallas TPU kernel: in-VMEM tile multisplit (the scatter's write combining).

Paper §4.4 / Fig. 3: scattering keys directly to their (r = 256) sub-bucket
chunks produces uncoalesced device-memory writes, so a thread block first
partitions its keys inside shared memory and then copies each sub-bucket as
one contiguous run.  The TPU translation stages the permutation in VMEM using
dense linear algebra instead of shared-memory atomics:

  1. one-hot cumulative counts give every key its *stable in-tile rank* within
     its digit (the shared-memory write counters of the paper),
  2. a KPB x KPB permutation matrix applied on the MXU moves the keys into
     digit-major order inside VMEM (exact: keys are split into 16-bit halves
     so the f32 MXU path is lossless),
  3. each digit's keys now form one contiguous run: the HBM write of a run is
     a single coalesced copy, and the run start offsets come from the global
     (scan of per-tile histograms) + in-tile exclusive offsets.

The per-thread "look-ahead" write combining of the paper is subsumed: a whole
run is combined by construction, for any skew.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _halves(x: jnp.ndarray, bits: int):
    """Split unsigned ints into exact 16-bit halves (f32-representable)."""
    n = (bits + 15) // 16
    return [((x >> jnp.array(16 * i, x.dtype)) &
             jnp.array(0xFFFF, x.dtype)).astype(jnp.float32)
            for i in range(n)]


def _from_halves(hs, dtype, bits: int):
    out = jnp.zeros(hs[0].shape, dtype)
    for i, h in enumerate(hs):
        out = out | (jnp.round(h).astype(dtype) << jnp.array(16 * i, dtype))
    return out


def _tile_partition(keys, *, shift: int, width: int):
    """Shared in-VMEM partition math: digits, per-digit run offsets, the
    (KPB, KPB) permutation matrix, and an ``apply`` closure that moves any
    payload through the MXU exactly (16-bit halves)."""
    r = 1 << width
    kpb = keys.shape[0]
    digit = ((keys >> jnp.array(shift, keys.dtype)) &
             jnp.array(r - 1, keys.dtype)).astype(jnp.int32)

    iota_r = jax.lax.broadcasted_iota(jnp.int32, (kpb, r), 1)
    onehot = (digit[:, None] == iota_r).astype(jnp.int32)      # (KPB, r)
    incl = jnp.cumsum(onehot, axis=0)
    excl_local = incl - onehot                                 # in-tile rank per digit
    hist = incl[-1]                                            # (r,)
    run_off = jnp.cumsum(hist) - hist                          # in-tile run starts

    # local destination of key i (digit-major slot) — gather-free via one-hot
    # (dtype pinned: under jax_enable_x64 an int32 sum would widen to int64)
    local_dest = jnp.sum(onehot * (run_off[None, :] + excl_local), axis=1,
                         dtype=jnp.int32)

    # permutation via MXU: M[j, i] = [local_dest[i] == j]
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (kpb, kpb), 0)
    perm = (iota_j == local_dest[None, :]).astype(jnp.float32)  # (KPB, KPB)

    def apply_perm(x, bits):
        hs = _halves(x, bits)
        out = [jax.lax.dot_general(perm, h[:, None], (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)[:, 0]
               for h in hs]
        return _from_halves(out, x.dtype, bits)

    sdig = jax.lax.dot_general(perm, digit.astype(jnp.float32)[:, None],
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)[:, 0]
    sorted_digit = jnp.round(sdig).astype(jnp.int32)
    pos = jax.lax.broadcasted_iota(jnp.int32, (kpb,), 0)
    onehot_s = (sorted_digit[:, None] == iota_r).astype(jnp.int32)
    rank = pos - jnp.sum(onehot_s * run_off[None, :], axis=1, dtype=jnp.int32)
    return apply_perm, sorted_digit, rank, hist


def _multisplit_kernel(keys_ref, sorted_ref, digit_ref, rank_ref, hist_ref, *,
                       shift: int, width: int, key_bits: int):
    keys = keys_ref[0]                                    # (KPB,)
    apply_perm, sorted_digit, rank, hist = _tile_partition(
        keys, shift=shift, width=width)
    sorted_ref[0] = apply_perm(keys, key_bits)
    digit_ref[0] = sorted_digit
    rank_ref[0] = rank
    hist_ref[0] = hist


def _multisplit_kv_kernel(keys_ref, vals_ref, sorted_ref, vout_ref, digit_ref,
                          rank_ref, hist_ref, *, shift: int, width: int,
                          key_bits: int, val_bits: int):
    """Key-value variant (§4.6): the same in-VMEM permutation matrix moves the
    values, which is exactly the paper's 'reuse the stored offsets for the
    value pass' — here the MXU applies the permutation twice instead of the
    thread replaying its recorded offsets."""
    keys = keys_ref[0]
    vals = vals_ref[0]
    apply_perm, sorted_digit, rank, hist = _tile_partition(
        keys, shift=shift, width=width)
    sorted_ref[0] = apply_perm(keys, key_bits)
    vout_ref[0] = apply_perm(vals, val_bits)
    digit_ref[0] = sorted_digit
    rank_ref[0] = rank
    hist_ref[0] = hist


@functools.partial(jax.jit, static_argnames=("shift", "width", "key_bits",
                                             "val_bits", "interpret"))
def tile_multisplit_kv(keys: jnp.ndarray, vals: jnp.ndarray, shift: int,
                       width: int, key_bits: int, val_bits: int,
                       interpret: bool = True):
    """(T, KPB) keys + values -> digit-major (keys, values, digits, ranks,
    histograms) — the pairs path of the scatter (paper §4.6)."""
    t, kpb = keys.shape
    r = 1 << width
    return pl.pallas_call(
        functools.partial(_multisplit_kv_kernel, shift=shift, width=width,
                          key_bits=key_bits, val_bits=val_bits),
        grid=(t,),
        in_specs=[pl.BlockSpec((1, kpb), lambda i: (i, 0)),
                  pl.BlockSpec((1, kpb), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, kpb), lambda i: (i, 0)),
                   pl.BlockSpec((1, kpb), lambda i: (i, 0)),
                   pl.BlockSpec((1, kpb), lambda i: (i, 0)),
                   pl.BlockSpec((1, kpb), lambda i: (i, 0)),
                   pl.BlockSpec((1, r), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((t, kpb), keys.dtype),
                   jax.ShapeDtypeStruct((t, kpb), vals.dtype),
                   jax.ShapeDtypeStruct((t, kpb), jnp.int32),
                   jax.ShapeDtypeStruct((t, kpb), jnp.int32),
                   jax.ShapeDtypeStruct((t, r), jnp.int32)],
        interpret=interpret,
    )(keys, vals)


@functools.partial(jax.jit, static_argnames=("shift", "width", "key_bits",
                                             "interpret"))
def tile_multisplit(keys: jnp.ndarray, shift: int, width: int,
                    key_bits: int, interpret: bool = True):
    """(T, KPB) keys -> (digit-major keys, digits, in-run ranks, histograms).

    After this kernel the HBM scatter is r contiguous run-copies per tile
    (start = global offset of (tile, digit) from the scanned histograms,
    length = hist[tile, digit]).
    """
    t, kpb = keys.shape
    r = 1 << width
    return pl.pallas_call(
        functools.partial(_multisplit_kernel, shift=shift, width=width,
                          key_bits=key_bits),
        grid=(t,),
        in_specs=[pl.BlockSpec((1, kpb), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, kpb), lambda i: (i, 0)),
                   pl.BlockSpec((1, kpb), lambda i: (i, 0)),
                   pl.BlockSpec((1, kpb), lambda i: (i, 0)),
                   pl.BlockSpec((1, r), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((t, kpb), keys.dtype),
                   jax.ShapeDtypeStruct((t, kpb), jnp.int32),
                   jax.ShapeDtypeStruct((t, kpb), jnp.int32),
                   jax.ShapeDtypeStruct((t, r), jnp.int32)],
        interpret=interpret,
    )(keys)
