"""Pallas TPU kernel: one fused launch per counting pass (§4.3–§4.4).

The paper's headline traffic reduction comes from *fusing* the three steps of
a counting pass — and the first step of the next pass — into one kernel:

  * §4.3: the scatter of pass i computes the digit histogram of pass i+1 on
    the keys it is already holding in registers/VMEM, so every pass after the
    first reads the keys ONCE (scatter) instead of twice (histogram +
    scatter): per-pass traffic drops from 2R+1W to 1R+1W key-array sweeps,
  * §4.4: keys are partitioned digit-major inside VMEM first, so the HBM
    writes are per-digit contiguous runs (write combining for any skew),
  * §4.2: the launch has a *constant* grid; each grid step reads its block
    descriptor (which segment, which offset, how many live lanes) from
    scalar-prefetched tables, so one compiled kernel serves every
    data-dependent set of active buckets.

``fused_counting_pass`` is that launch.  One call per pass:

  grid step g (sequential on TPU, so in-segment carries live in an
  accumulator) loops over the B block descriptors packed into its super-step
  (see *Batched grid steps* below); for each descriptor row:
    1. load the assigned KPB-block of keys (+ value slabs) from the *current*
       ping-pong buffer at a dynamic offset,
    2. extract the pass digit at a scalar-prefetched (lo, width) window —
       no pre-shifted key copies,
    3. one-hot cumulative counts give each key its stable in-block rank and
       the block histogram (the paper's shared-memory write counters),
    4. destination = segment base + in-segment digit offset (prefetched,
       from the histogram *fused out of the previous pass*) + carried
       in-segment block offset + rank; done-bucket gap blocks copy through
       at their own offsets,
    5. scatter keys and values into the *alternate* ping-pong buffer
       (``input_output_aliases`` donates it, §4.4's in-place replacement),
    6. fuse pass i+1: extract the next digit window and accumulate the
       per-next-active-segment histogram (§4.3) into an accumulator output.

The jnp drivers in ``repro.core`` compute identical permutations and serve as
oracles; ``repro.core.plan`` builds the descriptor tables.  On this CPU
container the kernel runs in interpret mode; on real hardware the dynamic
per-lane scatter of step 5 is realised as the r coalesced run copies of §4.4
(one static-size masked store per digit run) and the tables live in SMEM.

Batched grid steps (§4.2's over-decomposition, amortised)
---------------------------------------------------------
The descriptor tables arrive packed (``plan.pack_region_blocks``) as
(G', B) super-steps: grid step g owns the B consecutive descriptor rows
``[g*B, (g+1)*B)`` — padding rows on the masked tail carry ``count == 0``
and scatter nothing.  The kernel *vectorises* the super-step: B stacked
block loads, one batched (B, KPB, r) one-hot rank cumsum, one flattened
scatter per operand; only the in-segment carries run as a sequential
(r,)-vector recurrence over the B rows.  Packing rows *in descriptor order*
is what keeps every segment's carry chain intact: the blocks of one region
are consecutive rows, the TPU grid is sequential, and the in-step
recurrence is sequential, so the in-segment running offset accumulates
across rows and super-steps exactly as it did with one row per step.  The
launch-census invariant is untouched — one pass is still ONE ``pallas_call``
— but the grid shrinks from ``g_max`` to ``⌈g_max/B⌉``, dividing the
per-grid-step launch machinery by B and batching the rank compute into
fewer, larger ops (the actual interpret-mode win on this container).

Memory-transfer accounting per pass over n keys (k-bit, v-bit values):
  unfused (histogram launch + scatter launch):  keys 2R+1W, values 1R+1W
  fused   (this kernel):                        keys 1R+1W, values 1R+1W
plus one extra 1R histogram sweep for the very first pass (the prologue,
``initial_histogram``) — exactly the paper's accounting in §4.3/Table 2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.histogram import radix_histogram


def pad_length(n: int, kpb: int) -> int:
    """Padded ping-pong buffer length: whole KPB tiles plus one spare tile.

    The spare tile guarantees every dynamic block load ``[off, off + kpb)``
    with ``off <= n - 1`` stays in bounds, and slot ``n`` doubles as the
    in-bounds trash destination for masked lanes (no reliance on
    out-of-bounds scatter semantics).
    """
    return n + ((-n) % kpb) + kpb


def make_ping_pong(keys: jnp.ndarray, val_leaves, kpb: int):
    """Pad keys + value leaves into (current, alternate) ping-pong buffers.

    Key padding is the all-ones sentinel so the prologue histogram can
    subtract it from the top digit bucket; value padding is zeros.  Returns
    ``(cur_keys, cur_vals), (alt_keys, alt_vals)`` with ``vals`` as tuples.
    """
    n = keys.shape[0]
    n_pad = pad_length(n, kpb)
    sentinel = ~jnp.zeros((), keys.dtype)
    ck = jnp.concatenate([keys, jnp.full((n_pad - n,), sentinel, keys.dtype)])
    cv = tuple(
        jnp.concatenate([v, jnp.zeros((n_pad - n,) + v.shape[1:], v.dtype)])
        for v in val_leaves)
    ak = jnp.full_like(ck, sentinel)
    av = tuple(jnp.zeros_like(v) for v in cv)
    return (ck, cv), (ak, av)


def initial_histogram(buf_keys: jnp.ndarray, n: int, lo: int, width: int,
                      r: int, a_max: int, kpb: int,
                      interpret: bool = True) -> jnp.ndarray:
    """Histogram of the first pass's digit over the single segment [0, n).

    This is the one unfused key sweep of the whole sort (§4.3: pass 0 has no
    previous scatter to fuse with).  ``buf_keys`` is a sentinel-padded
    ping-pong buffer from ``make_ping_pong``; the sentinels extract the
    all-ones digit and are subtracted from the top bucket.  Returns the
    (a_max, r) per-active-segment histogram table with row 0 populated.
    """
    r0 = 1 << width
    tiles = buf_keys.reshape(-1, kpb)
    hist = radix_histogram(tiles, lo, width, interpret=interpret).sum(
        axis=0, dtype=jnp.int32)   # pinned: x64 would widen the accumulator
    hist = hist.at[r0 - 1].add(-(buf_keys.shape[0] - n))
    out = jnp.zeros((a_max, r), jnp.int32)
    return out.at[0, :r0].set(hist)


def _fused_pass_kernel(sc_ref, seg_ref, off_ref, reset_ref, cnt_ref, act_ref,
                       *refs, kpb: int, r: int, a_max: int, n: int,
                       num_vals: int, batch: int, lookahead: bool):
    """One grid step = one packed super-step of ``batch`` descriptor rows
    (see module docstring)."""
    srck_ref = refs[0]
    srcv_refs = refs[1:1 + num_vals]
    # refs[1+num_vals : 1+2*num_vals+1] are the aliased alternate buffers —
    # present only to donate their memory to the outputs; never read.
    bexcl_ref = refs[2 + 2 * num_vals]
    nsid_ref = refs[3 + 2 * num_vals]
    dstk_ref = refs[4 + 2 * num_vals]
    dstv_refs = refs[5 + 2 * num_vals:5 + 3 * num_vals]
    hist_ref = refs[5 + 3 * num_vals]
    if lookahead:
        hist2_ref = refs[6 + 3 * num_vals]
        carry_ref = refs[7 + 3 * num_vals]
    else:
        carry_ref = refs[6 + 3 * num_vals]

    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        carry_ref[...] = jnp.zeros_like(carry_ref)
        if lookahead:
            hist2_ref[...] = jnp.zeros_like(hist2_ref)

    kdt = srck_ref.dtype
    one = jnp.ones((), kdt)
    lane = jax.lax.iota(jnp.int32, kpb)
    # pass digit windows at the scalar-prefetched slots — no pre-shifted keys
    lo = sc_ref[0].astype(kdt)
    width = sc_ref[1].astype(kdt)
    nlo = sc_ref[2].astype(kdt)
    nwidth = sc_ref[3].astype(kdt)

    # per-row descriptors of the super-step, vectorised over the B rows
    a = seg_ref[g]                               # compact active idx (or a_max)
    off = off_ref[g]                             # first key per block
    cnt = cnt_ref[g]                             # live lanes (0 = padding row)
    act = act_ref[g]                             # 1 = partition, 0 = copy
    reset = reset_ref[g]                         # 1 = first block of region

    # B block loads (the ONE key read of the pass, §4.3), stacked (B, kpb)
    keys = jnp.stack([srck_ref[pl.ds(off[j], kpb)] for j in range(batch)])
    lv = lane[None, :] < cnt[:, None]
    digit = ((keys >> lo) & ((one << width) - one)).astype(jnp.int32)

    # stable in-block rank per digit + per-row block histograms (§4.4's
    # write counters), ONE batched one-hot cumsum for the whole super-step
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (batch, kpb, r), 2)
    onehot = ((digit[:, :, None] == iota_r) & lv[:, :, None]).astype(jnp.int32)
    incl = jnp.cumsum(onehot, axis=1)
    hv = incl[:, kpb - 1, :]                                     # (B, r)
    rank = jnp.take_along_axis(incl, digit[:, :, None], axis=2)[..., 0] - 1

    # in-segment carry chain across the packed rows: rows stay in descriptor
    # order, so a tiny sequential (r,)-vector recurrence over the B rows —
    # reset on region firsts — extends the cross-step accumulator exactly
    asafe = jnp.clip(a, 0, a_max - 1)
    carry = carry_ref[...]
    row_carries = []
    for j in range(batch):
        carry = jnp.where(reset[j] == 1, jnp.zeros((r,), jnp.int32), carry)
        row_carries.append(carry)
        carry = carry + hv[j]
    carry_ref[...] = carry
    base_rows = bexcl_ref[asafe] + jnp.stack(row_carries)        # (B, r)

    # destination: segment base + in-segment digit offset (fused out of the
    # previous pass) + in-segment block carry + in-block rank
    dest_part = jnp.take_along_axis(base_rows, digit, axis=1) + rank
    gidx = off[:, None] + lane[None, :]
    dest = jnp.where(lv, jnp.where(act[:, None] == 1, dest_part, gidx), n)

    # ONE write of the pass, a single flattened scatter for all B rows: on
    # TPU these per-lane stores lower to the r coalesced per-digit run
    # copies of §4.4 (keys are run-contiguous per digit after ranking);
    # slot n swallows masked lanes.
    flat_dest = dest.reshape(-1)
    dstk_ref[flat_dest] = keys.reshape(-1)
    for sv_ref, dv_ref in zip(srcv_refs, dstv_refs):
        vals = jnp.stack([sv_ref[pl.ds(off[j], kpb)] for j in range(batch)])
        dv_ref[flat_dest] = vals.reshape(-1)

    # §4.3 fusion: the digit histogram of pass i+1, keyed by the compact id
    # of the sub-bucket's next-pass segment (a_max rows suffice: R3 makes
    # every next-pass active bucket a single > ∂̂ sub-bucket).
    ndig = ((keys >> nlo) & ((one << nwidth) - one)).astype(jnp.int32)
    sid = nsid_ref[...][asafe[:, None] * r + jnp.clip(digit, 0, r - 1)]
    live = (lv & (act[:, None] == 1) & (sid < a_max) & (sc_ref[3] > 0))
    flat = jnp.where(live, sid * r + ndig, 0).reshape(-1)
    h = hist_ref[...]
    hist_ref[...] = h.at[flat].add(live.reshape(-1).astype(jnp.int32))

    if lookahead:
        # adaptive lookahead (§4.3 extended): also histogram the window of
        # pass i+2, keyed by the SAME next-pass segment id.  The table is
        # exactly the i+2 histogram whenever pass i+1 is elided: an elidable
        # pass has one occupied digit per active segment, so its bookkeeping
        # maps each segment 1:1 onto the same compact id and moves no keys.
        n2lo = sc_ref[4].astype(kdt)
        n2width = sc_ref[5].astype(kdt)
        ndig2 = ((keys >> n2lo) & ((one << n2width) - one)).astype(jnp.int32)
        live2 = (lv & (act[:, None] == 1) & (sid < a_max) & (sc_ref[5] > 0))
        flat2 = jnp.where(live2, sid * r + ndig2, 0).reshape(-1)
        h2 = hist2_ref[...]
        hist2_ref[...] = h2.at[flat2].add(live2.reshape(-1).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("kpb", "r", "a_max", "n",
                                             "interpret", "lookahead"))
def fused_counting_pass(src_keys, src_vals, alt_keys, alt_vals, pass_scalars,
                        blk_seg, blk_off, blk_reset, blk_count, blk_active,
                        base_excl, next_sid, *, kpb: int, r: int, a_max: int,
                        n: int, interpret: bool = True,
                        lookahead: bool = False):
    """One full counting pass over all active buckets in ONE Pallas launch.

    Arguments:
      src_keys / src_vals     — current ping-pong buffers (``pad_length`` long;
                                vals is a tuple of arrays with leading axis
                                matching the keys),
      alt_keys / alt_vals     — alternate buffers, donated to the outputs via
                                ``input_output_aliases`` (§4.4 in-place
                                replacement),
      pass_scalars            — int32 [lo, width, next_lo, next_width] digit
                                windows (``plan.digit_window``); with
                                ``lookahead`` two extra slots
                                [next2_lo, next2_width] locate the pass-i+2
                                window the adaptive schedule histograms
                                alongside,
      blk_*                   — int32 block descriptor tables
                                (``plan.make_region_blocks``): compact segment
                                index (a_max = copy-through), key offset,
                                carry-reset flag, live-lane count, active
                                flag.  Either flat (G,) rows (one per grid
                                step) or (G', B) super-steps packed by
                                ``plan.pack_region_blocks`` — the grid is the
                                leading axis either way,
      base_excl               — (a_max, r) int32 absolute run starts per
                                (active segment, digit): base + exclusive scan
                                of the carried histogram,
      next_sid                — (a_max * r,) int32 map from (segment, digit)
                                sub-bucket to its compact next-pass active
                                segment id (a_max = done / not active).

    Returns ``(new_keys, new_vals, hist_next)`` where ``hist_next`` is the
    (a_max * r,) fused histogram of the NEXT pass's digit (reshape to
    (a_max, r)); row j matches the j-th next-pass active segment in position
    order.  With ``lookahead=True`` the return gains a fourth element
    ``hist_next2`` — the pass-i+2 window histogrammed under the same
    next-pass segment keys, which is exactly pass i+2's histogram whenever
    pass i+1 is elided (single occupied digit per segment => identity
    scatter and a 1:1 segment map).  Exactly one ``pallas_call`` in the
    trace either way — the property the launch-counter regression test pins
    down.
    """
    if blk_seg.ndim == 1:                    # flat rows = B=1 super-steps
        blk_seg, blk_off, blk_reset, blk_count, blk_active = (
            t.reshape(-1, 1)
            for t in (blk_seg, blk_off, blk_reset, blk_count, blk_active))
    g_steps, batch = blk_seg.shape
    num_vals = len(src_vals)
    n_pad = src_keys.shape[0]

    whole = lambda x: pl.BlockSpec(x.shape, lambda i, *_: (0,) * x.ndim)
    hists = 2 if lookahead else 1
    in_specs = ([whole(src_keys)] + [whole(v) for v in src_vals] +
                [whole(alt_keys)] + [whole(v) for v in alt_vals] +
                [whole(base_excl), whole(next_sid)])
    out_specs = ([whole(src_keys)] + [whole(v) for v in src_vals] +
                 [pl.BlockSpec((a_max * r,), lambda i, *_: (0,))] * hists +
                 [pl.BlockSpec((r,), lambda i, *_: (0,))])
    out_shape = ([jax.ShapeDtypeStruct((n_pad,), src_keys.dtype)] +
                 [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in src_vals] +
                 [jax.ShapeDtypeStruct((a_max * r,), jnp.int32)] * hists +
                 [jax.ShapeDtypeStruct((r,), jnp.int32)])
    # operand index space includes the 6 scalar-prefetch args; the alternate
    # buffers (inputs 6+1+num_vals ...) donate their memory to the outputs
    alt0 = 6 + 1 + num_vals
    aliases = {alt0 + i: i for i in range(1 + num_vals)}

    out = pl.pallas_call(
        functools.partial(_fused_pass_kernel, kpb=kpb, r=r, a_max=a_max,
                          n=n, num_vals=num_vals, batch=batch,
                          lookahead=lookahead),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6,
            grid=(g_steps,),
            in_specs=in_specs,
            out_specs=out_specs,
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(pass_scalars, blk_seg, blk_off, blk_reset, blk_count, blk_active,
      src_keys, *src_vals, alt_keys, *alt_vals, base_excl, next_sid)

    new_keys = out[0]
    new_vals = tuple(out[1:1 + num_vals])
    hist_next = out[1 + num_vals]
    if lookahead:
        return new_keys, new_vals, hist_next, out[2 + num_vals]
    return new_keys, new_vals, hist_next
