"""Pallas TPU kernel: one fused launch per counting pass (§4.3–§4.4).

The paper's headline traffic reduction comes from *fusing* the three steps of
a counting pass — and the first step of the next pass — into one kernel:

  * §4.3: the scatter of pass i computes the digit histogram of pass i+1 on
    the keys it is already holding in registers/VMEM, so every pass after the
    first reads the keys ONCE (scatter) instead of twice (histogram +
    scatter): per-pass traffic drops from 2R+1W to 1R+1W key-array sweeps,
  * §4.4: keys are partitioned digit-major inside VMEM first, so the HBM
    writes are per-digit contiguous runs (write combining for any skew),
  * §4.2: the launch has a *constant* grid; each grid step reads its block
    descriptor (which segment, which offset, how many live lanes) from
    scalar-prefetched tables, so one compiled kernel serves every
    data-dependent set of active buckets.

``fused_counting_pass`` is that launch.  One call per pass:

  grid step g (sequential on TPU, so in-segment carries live in an
  accumulator):
    1. load the assigned KPB-block of keys (+ value slabs) from the *current*
       ping-pong buffer at a dynamic offset,
    2. extract the pass digit at a scalar-prefetched (lo, width) window —
       no pre-shifted key copies,
    3. one-hot cumulative counts give each key its stable in-block rank and
       the block histogram (the paper's shared-memory write counters),
    4. destination = segment base + in-segment digit offset (prefetched,
       from the histogram *fused out of the previous pass*) + carried
       in-segment block offset + rank; done-bucket gap blocks copy through
       at their own offsets,
    5. scatter keys and values into the *alternate* ping-pong buffer
       (``input_output_aliases`` donates it, §4.4's in-place replacement),
    6. fuse pass i+1: extract the next digit window and accumulate the
       per-next-active-segment histogram (§4.3) into an accumulator output.

The jnp drivers in ``repro.core`` compute identical permutations and serve as
oracles; ``repro.core.plan`` builds the descriptor tables.  On this CPU
container the kernel runs in interpret mode; on real hardware the dynamic
per-lane scatter of step 5 is realised as the r coalesced run copies of §4.4
(one static-size masked store per digit run) and the tables live in SMEM.

Memory-transfer accounting per pass over n keys (k-bit, v-bit values):
  unfused (histogram launch + scatter launch):  keys 2R+1W, values 1R+1W
  fused   (this kernel):                        keys 1R+1W, values 1R+1W
plus one extra 1R histogram sweep for the very first pass (the prologue,
``initial_histogram``) — exactly the paper's accounting in §4.3/Table 2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.histogram import radix_histogram


def pad_length(n: int, kpb: int) -> int:
    """Padded ping-pong buffer length: whole KPB tiles plus one spare tile.

    The spare tile guarantees every dynamic block load ``[off, off + kpb)``
    with ``off <= n - 1`` stays in bounds, and slot ``n`` doubles as the
    in-bounds trash destination for masked lanes (no reliance on
    out-of-bounds scatter semantics).
    """
    return n + ((-n) % kpb) + kpb


def make_ping_pong(keys: jnp.ndarray, val_leaves, kpb: int):
    """Pad keys + value leaves into (current, alternate) ping-pong buffers.

    Key padding is the all-ones sentinel so the prologue histogram can
    subtract it from the top digit bucket; value padding is zeros.  Returns
    ``(cur_keys, cur_vals), (alt_keys, alt_vals)`` with ``vals`` as tuples.
    """
    n = keys.shape[0]
    n_pad = pad_length(n, kpb)
    sentinel = ~jnp.zeros((), keys.dtype)
    ck = jnp.concatenate([keys, jnp.full((n_pad - n,), sentinel, keys.dtype)])
    cv = tuple(
        jnp.concatenate([v, jnp.zeros((n_pad - n,) + v.shape[1:], v.dtype)])
        for v in val_leaves)
    ak = jnp.full_like(ck, sentinel)
    av = tuple(jnp.zeros_like(v) for v in cv)
    return (ck, cv), (ak, av)


def initial_histogram(buf_keys: jnp.ndarray, n: int, lo: int, width: int,
                      r: int, a_max: int, kpb: int,
                      interpret: bool = True) -> jnp.ndarray:
    """Histogram of the first pass's digit over the single segment [0, n).

    This is the one unfused key sweep of the whole sort (§4.3: pass 0 has no
    previous scatter to fuse with).  ``buf_keys`` is a sentinel-padded
    ping-pong buffer from ``make_ping_pong``; the sentinels extract the
    all-ones digit and are subtracted from the top bucket.  Returns the
    (a_max, r) per-active-segment histogram table with row 0 populated.
    """
    r0 = 1 << width
    tiles = buf_keys.reshape(-1, kpb)
    hist = radix_histogram(tiles, lo, width, interpret=interpret).sum(
        axis=0, dtype=jnp.int32)   # pinned: x64 would widen the accumulator
    hist = hist.at[r0 - 1].add(-(buf_keys.shape[0] - n))
    out = jnp.zeros((a_max, r), jnp.int32)
    return out.at[0, :r0].set(hist)


def _fused_pass_kernel(sc_ref, seg_ref, off_ref, reset_ref, cnt_ref, act_ref,
                       *refs, kpb: int, r: int, a_max: int, n: int,
                       num_vals: int):
    """One grid step = one block descriptor row (see module docstring)."""
    srck_ref = refs[0]
    srcv_refs = refs[1:1 + num_vals]
    # refs[1+num_vals : 1+2*num_vals+1] are the aliased alternate buffers —
    # present only to donate their memory to the outputs; never read.
    bexcl_ref = refs[2 + 2 * num_vals]
    nsid_ref = refs[3 + 2 * num_vals]
    dstk_ref = refs[4 + 2 * num_vals]
    dstv_refs = refs[5 + 2 * num_vals:5 + 3 * num_vals]
    hist_ref = refs[5 + 3 * num_vals]
    carry_ref = refs[6 + 3 * num_vals]

    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = seg_ref[g]                               # compact active idx (or a_max)
    off = off_ref[g]                             # first key of the block
    cnt = cnt_ref[g]                             # live lanes in the block
    act = act_ref[g]                             # 1 = partition, 0 = copy-through
    reset = reset_ref[g]                         # 1 = first block of its region

    keys = srck_ref[pl.ds(off, kpb)]             # ONE read of the pass (§4.3)
    kdt = keys.dtype
    one = jnp.ones((), kdt)
    lane = jax.lax.iota(jnp.int32, kpb)
    lv = lane < cnt

    # pass digit at the scalar-prefetched window — no pre-shifted key copies
    lo = sc_ref[0].astype(kdt)
    width = sc_ref[1].astype(kdt)
    digit = ((keys >> lo) & ((one << width) - one)).astype(jnp.int32)

    # stable in-block rank per digit + block histogram (§4.4's counters)
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (kpb, r), 1)
    onehot = ((digit[:, None] == iota_r) & lv[:, None]).astype(jnp.int32)
    incl = jnp.cumsum(onehot, axis=0)
    hv = incl[kpb - 1]                                           # (r,)
    excl = incl - onehot

    # destination: segment base + in-segment digit offset (fused out of the
    # previous pass) + in-segment block carry + in-block rank
    asafe = jnp.clip(a, 0, a_max - 1)
    carry_prev = jnp.where(reset == 1, jnp.zeros((r,), jnp.int32),
                           carry_ref[...])
    base_row = bexcl_ref[asafe] + carry_prev                     # (r,)
    dest_part = jnp.sum(onehot * (base_row[None, :] + excl), axis=1,
                        dtype=jnp.int32)
    gidx = off + lane
    dest = jnp.where(lv, jnp.where(act == 1, dest_part, gidx), n)

    # ONE write of the pass: on TPU these per-lane stores lower to the r
    # coalesced per-digit run copies of §4.4 (keys are run-contiguous per
    # digit after ranking); slot n swallows masked lanes.
    dstk_ref[dest] = keys
    for sv_ref, dv_ref in zip(srcv_refs, dstv_refs):
        dv_ref[dest] = sv_ref[pl.ds(off, kpb)]
    carry_ref[...] = carry_prev + hv

    # §4.3 fusion: the digit histogram of pass i+1, keyed by the compact id
    # of the sub-bucket's next-pass segment (a_max rows suffice: R3 makes
    # every next-pass active bucket a single > ∂̂ sub-bucket).
    nlo = sc_ref[2].astype(kdt)
    nwidth = sc_ref[3].astype(kdt)
    ndig = ((keys >> nlo) & ((one << nwidth) - one)).astype(jnp.int32)
    sid = nsid_ref[...][asafe * r + jnp.clip(digit, 0, r - 1)]
    live = lv & (act == 1) & (sid < a_max) & (sc_ref[3] > 0)
    flat = jnp.where(live, sid * r + ndig, 0)
    h = hist_ref[...]
    hist_ref[...] = h.at[flat].add(live.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("kpb", "r", "a_max", "n",
                                             "interpret"))
def fused_counting_pass(src_keys, src_vals, alt_keys, alt_vals, pass_scalars,
                        blk_seg, blk_off, blk_reset, blk_count, blk_active,
                        base_excl, next_sid, *, kpb: int, r: int, a_max: int,
                        n: int, interpret: bool = True):
    """One full counting pass over all active buckets in ONE Pallas launch.

    Arguments:
      src_keys / src_vals     — current ping-pong buffers (``pad_length`` long;
                                vals is a tuple of arrays with leading axis
                                matching the keys),
      alt_keys / alt_vals     — alternate buffers, donated to the outputs via
                                ``input_output_aliases`` (§4.4 in-place
                                replacement),
      pass_scalars            — (4,) int32 [lo, width, next_lo, next_width]
                                digit windows (``plan.digit_window``),
      blk_*                   — (G,) int32 block descriptor tables
                                (``plan.make_region_blocks``): compact segment
                                index (a_max = copy-through), key offset,
                                carry-reset flag, live-lane count, active flag,
      base_excl               — (a_max, r) int32 absolute run starts per
                                (active segment, digit): base + exclusive scan
                                of the carried histogram,
      next_sid                — (a_max * r,) int32 map from (segment, digit)
                                sub-bucket to its compact next-pass active
                                segment id (a_max = done / not active).

    Returns ``(new_keys, new_vals, hist_next)`` where ``hist_next`` is the
    (a_max * r,) fused histogram of the NEXT pass's digit (reshape to
    (a_max, r)); row j matches the j-th next-pass active segment in position
    order.  Exactly one ``pallas_call`` in the trace — the property the
    launch-counter regression test pins down.
    """
    g_max = blk_seg.shape[0]
    num_vals = len(src_vals)
    n_pad = src_keys.shape[0]

    whole = lambda x: pl.BlockSpec(x.shape, lambda i, *_: (0,) * x.ndim)
    in_specs = ([whole(src_keys)] + [whole(v) for v in src_vals] +
                [whole(alt_keys)] + [whole(v) for v in alt_vals] +
                [whole(base_excl), whole(next_sid)])
    out_specs = ([whole(src_keys)] + [whole(v) for v in src_vals] +
                 [pl.BlockSpec((a_max * r,), lambda i, *_: (0,)),
                  pl.BlockSpec((r,), lambda i, *_: (0,))])
    out_shape = ([jax.ShapeDtypeStruct((n_pad,), src_keys.dtype)] +
                 [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in src_vals] +
                 [jax.ShapeDtypeStruct((a_max * r,), jnp.int32),
                  jax.ShapeDtypeStruct((r,), jnp.int32)])
    # operand index space includes the 6 scalar-prefetch args; the alternate
    # buffers (inputs 6+1+num_vals ...) donate their memory to the outputs
    alt0 = 6 + 1 + num_vals
    aliases = {alt0 + i: i for i in range(1 + num_vals)}

    out = pl.pallas_call(
        functools.partial(_fused_pass_kernel, kpb=kpb, r=r, a_max=a_max,
                          n=n, num_vals=num_vals),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6,
            grid=(g_max,),
            in_specs=in_specs,
            out_specs=out_specs,
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(pass_scalars, blk_seg, blk_off, blk_reset, blk_count, blk_active,
      src_keys, *src_vals, alt_keys, *alt_vals, base_excl, next_sid)

    new_keys = out[0]
    new_vals = tuple(out[1:1 + num_vals])
    hist_next = out[1 + num_vals]
    return new_keys, new_vals, hist_next
