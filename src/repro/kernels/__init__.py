"""Pallas TPU kernels for the hybrid radix sort's compute hot spots.

histogram   — one-hot MXU contraction histogram (§4.3's atomics, TPU-native)
multisplit  — in-VMEM tile partition + write combining (§4.4 / Fig. 3)
bitonic     — VMEM local sort (§4.1's local sort; CUB BlockRadixSort analogue)
assigned    — scalar-prefetch block descriptors (§4.2 constant-invocation trick)
ops         — jit'd composition into full counting passes
ref         — pure-jnp oracles
"""
from repro.kernels.histogram import radix_histogram
from repro.kernels.multisplit import tile_multisplit, tile_multisplit_kv
from repro.kernels.bitonic import bitonic_sort_rows, bitonic_sort_rows_kv
from repro.kernels.assigned import assigned_histogram
from repro.kernels.ops import kernel_counting_pass, kernel_local_sort

__all__ = ["radix_histogram", "tile_multisplit", "tile_multisplit_kv", "bitonic_sort_rows",
           "bitonic_sort_rows_kv", "assigned_histogram",
           "kernel_counting_pass", "kernel_local_sort"]
