"""Pallas TPU kernels for the hybrid radix sort's compute hot spots.

histogram   — one-hot MXU contraction histogram (§4.3's atomics, TPU-native)
multisplit  — in-VMEM tile partition + write combining (§4.4 / Fig. 3)
bitonic     — VMEM local sort (§4.1's local sort; CUB BlockRadixSort analogue)
assigned    — scalar-prefetch block descriptors (§4.2 constant-invocation trick)
ops         — jit'd composition into full counting passes (the sort's engine)
ref         — pure-jnp oracles
"""
from repro.kernels.histogram import radix_histogram
from repro.kernels.multisplit import tile_multisplit, tile_multisplit_kv
from repro.kernels.bitonic import (bitonic_sort_rows, bitonic_sort_rows_kv,
                                   bitonic_sort_rows_stable)
from repro.kernels.assigned import (assigned_histogram, BlockAssignment,
                                    make_block_assignments)
from repro.kernels.ops import (kernel_counting_pass, kernel_counting_pass_kv,
                               kernel_pass_perm, kernel_local_sort,
                               segmented_kernel_pass, segmented_local_sort,
                               tile_histogram_pass)

__all__ = [
    "radix_histogram", "tile_multisplit", "tile_multisplit_kv",
    "bitonic_sort_rows", "bitonic_sort_rows_kv", "bitonic_sort_rows_stable",
    "assigned_histogram", "BlockAssignment", "make_block_assignments",
    "kernel_counting_pass", "kernel_counting_pass_kv", "kernel_pass_perm",
    "kernel_local_sort", "segmented_kernel_pass", "segmented_local_sort",
    "tile_histogram_pass",
]
