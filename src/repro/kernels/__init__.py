"""Pallas TPU kernels for the hybrid radix sort's compute hot spots.

fused       — ONE launch per counting pass: block-descriptor partition +
              coalesced scatter of pass i fused with the digit histogram of
              pass i+1, on donated ping-pong buffers (§4.2–§4.4)
merge       — ONE launch per k-way merge round (§5): merge-path diagonal
              partition of K sorted runs per output tile (per-run-pair
              searchsorted co-ranks inside the tile), coalesced merge
              writes with KV payloads, donated ping-pong buffers — the
              device half of ``core.outofcore``'s pipelined sort; plus the
              host-side partition math (``host_coranks``,
              ``spill_group_plan``) that cuts groups of host-spilled runs
              into device-slab-sized strips, ONE launch per slab sweep
histogram   — one-hot MXU contraction histogram (§4.3's atomics, TPU-native)
multisplit  — in-VMEM tile partition + write combining (§4.4 / Fig. 3); the
              fused pass's per-block partition math, kept as the standalone
              per-tile kernel and oracle
bitonic     — VMEM local sort (§4.1's local sort; CUB BlockRadixSort analogue)
assigned    — the scalar-prefetch launch exemplar (§4.2 constant-invocation
              trick; descriptor *generation* lives in core.plan)
ops         — local-sort / histogram drivers (the fused counting passes moved
              to ``fused``; the per-bucket multi-launch drivers are retired)
ref         — pure-jnp oracles

Memory-transfer accounting (paper §4.3–§4.4, the roofline target for
BENCH_hybrid.json): one *unfused* counting pass over n keys of b bytes moves
``2R + 1W`` key sweeps (histogram read + scatter read + scatter write) =
3·n·b bytes; values add ``1R + 1W`` = 2·n·v.  The fused pass moves
``1R + 1W`` = 2·n·b (+ 2·n·v) because pass i+1's histogram is computed while
pass i's scatter still holds the keys — a 1.5x per-pass key-traffic
reduction, and the whole sort pays exactly one extra 1R prologue sweep
(pass 0's histogram).  A full k-bit hybrid sort therefore moves at most
``(2·⌈k/d⌉ + 1)·n·b`` key bytes versus ``3·⌈k/d⌉·n·b`` unfused and versus
``3·⌈k/5⌉·n·b`` for the CUB-style LSD baseline — the paper's 1.6–1.75x
traffic headline.  Bookkeeping arrays (M2–M5 of §4.5) are O(n/∂̂ · r) and do
not change the leading term.
[verified-by: ``repro.analysis`` contracts ``hybrid_sort`` /
``hybrid_sort_kv`` / ``lsd_sort`` / ``single_pass_partition``, checks
``transfer.hbm_bytes`` (the formula above, re-derived from traced operand
shapes), ``census`` (one launch per pass) and ``donation`` (the ping-pong
aliases the 1R+1W claim depends on); ``python -m repro.analysis``]

Entropy-adaptive row (``core.hybrid`` adaptive schedule + ``core.bijection``
compressed keys): only *executed* passes move bytes — statically dead bits
shrink the nominal schedule to ⌈k_eff/d⌉ over the live window, the fused
launch's free next-pass histogram elides single-occupied-digit passes with
no launch at all, and opt-in key compression shrinks b itself to the packed
carrier b_eff (uint64 → uint32 when ≤ 32 bits are live).  The adaptive
bound is therefore

    ``(2·p_exec + 1)·n·b_eff``  key bytes,  ``p_exec ≤ ⌈k_eff/d⌉ ≤ ⌈k/d⌉``

with equality on full-entropy keys (zero overhead: the skip predicate reads
the histogram the fused pass already produced) and p_exec → 1 on clustered
/ shared-prefix keys.  Executed-vs-nominal counts are census-gated (one
``pallas_call`` per *executed* pass — elided passes launch nothing;
tests/test_adaptive.py) and reported per entropy rung by the
``entropy/...`` rows of BENCH_hybrid.json and ``SortStats.elided_passes``.

Out-of-core transfer accounting (§5, the BENCH_ooc.json roofline row): for
N keys in C = ⌈N/chunk⌉ device-sized chunks merged K ways per round, per
key of b bytes (values: v bytes):

| phase                       | host-link bytes | device sweeps (R+W)        |
|-----------------------------|-----------------|----------------------------|
| chunk staging (device_put)  | 1·(b+v)         | —  (overlapped with sorts) |
| chunk sorts (fused engine)  | —               | (2·⌈k/d⌉ + 1)·b + 2·⌈k/d⌉·v|
| run marshalling (concat +   | —               | 3·(b+v)  (1R + 2W, once)   |
|   alternate-buffer fill)    |                 |                            |
| merge rounds (merge kernel) | —               | 2·⌈log_K C⌉·(b+v)          |
| spill rounds (host-resident | 2·(b+v) each    | 2·(b+v) each (slab-sized   |
|   runs, slab-streamed merge)|                 | buffers only)              |
| result gather               | 1·(b+v)         | —  (spill: runs gathered   |
|                             |                 |    during the chunk phase) |

Device-resident regime (rows 1–4 + gather): every key crosses the host link
exactly twice regardless of C (the §5 pipeline hides the upload behind the
previous chunk's sort), and each merge round reads and writes the whole run
buffer once — one ``pallas_call`` per round, ⌈log_K C⌉ rounds.  The chunk
sorts inherit the adaptive bound above (⌈k/d⌉ → p_exec per chunk, totalled
in ``OocStats.chunk_passes_executed``), and ``oocsort(compress=True)``
replaces b with the packed carrier b_eff in EVERY row — link bytes, slab
sizing and spill budgets included — before any key crosses the link.  Host-spill
regime (``oocsort(spill_budget_bytes=...)``): run marshalling and the flat
merge buffers disappear — runs live host-side between rounds, every spilled
round streams each multi-run group through fixed device slabs (strip i+1's
upload and strip i−1's download in flight around strip i's launch, one
``pallas_call`` per group-slab sweep), and total host crossings are
``2·N·(b+v)·(1 + rounds_spilled)`` — leftover single-run groups carry over
host-side for free, and device bytes stay bounded by the budget
(``OocStats.device_high_water_bytes``) no matter how large N grows, which
is what makes the §5 beyond-device-memory claim literal.  The merge-path
diagonal searches add O(tiles · K · log chunk) gathered (host-spill:
probed) elements and O(G·K) int32 descriptor uploads per strip, sub-leading
for any real tile size.  On this CPU container interpret-mode overhead
dominates, so the tracked proxy is the argsort/ooc ratio trajectory in
BENCH_ooc.json (``spill/...`` rows for the streamed regime) plus the
structural census (``utils.hlo.launch_census``).
[verified-by: contracts ``ooc_chunk_sort`` / ``ooc_merge_round`` /
``ooc_slab_sweep`` — ``transfer.hbm_bytes`` pins the 2·(b+v) device sweep
per merge/slab row, ``census`` the one-launch-per-round gate, and the
``descriptor_tables`` report proves the merge-path/spill tables write
disjoint, exactly-covering output ranges]

Distributed-exchange accounting (``core.distributed``, the BENCH_dist.json
device-scaling row): for n_local keys of b bytes (+ v payload bytes) per
shard over P shards, per *executed* exchange attempt (attempts ledgered in
``DistStats.exchange_attempts``; re-samples replay every row below):

| exchange phase                  | ICI wire bytes per shard              |
|---------------------------------|---------------------------------------|
| splitter sample (all_gather)    | s·b·(P−1)  (s = oversample·refine^a)  |
| key exchange (all_to_all)       | 2·n_local·b·(P−1)/P·slack  (1 send +  |
|                                 |   1 receive crossing per key, padded) |
| payload exchange (all_to_all)   | 2·n_local·v·(P−1)/P·slack  per leaf   |
| count exchange + overflow psum  | O(P)·4  (sub-leading)                 |

Device sweeps stay the single-shard tables above at n_local/C per chunk:
the local chunk sorts pay the fused/adaptive bound, each attempt's shard
bucketing is ONE fused counting pass (2·n_local·b sweeps), and the finish
is one high-fan-in multiway merge (2·n_local·b·⌈log2(C·P)⌉ searchsorted
sweeps) plus one 2-bucket compaction pass.  The sample term is what the
oversampling ratio trades: s·P·b gathered bytes buy splitter rank error
≈ n_local·P/(s·P) keys, so doubling s halves the skew the slack capacity
must absorb — the ≤ 2x clustered-skew gate in
tests/test_distributed_property.py pins the quality side, and
``utils.hlo.collective_bytes`` reads the wire side off the lowered HLO.
[verified-by: contract ``distributed_shard`` — ``transfer.link_bytes``
re-derives every row from the collective-primitive result shapes in the
traced shard body (per-kind site counts included) and diffs them against
the declared formula in ``core.distributed.ANALYSIS_CONTRACT``]

Failure & recovery accounting (``core.faults``, the fault-replay wall in
tests/test_faults.py): resilience must not silently bend the tables above,
so its costs are ledgered separately and the clean formulas stay exact.

  * Retries — a failed *transfer* attempt still crossed the link before it
    was declared lost (the worst-case model), so each transient fault at a
    transfer site re-pays that site's payload bytes; failed *launches*
    re-pay nothing on the link.  The extra bytes accumulate in
    ``OocStats.retry_link_bytes`` (split h2d/d2h internally), never in the
    per-phase columns, giving the test-asserted identity::

        h2d_bytes + d2h_bytes ==
            chunk_link_bytes + spill_link_bytes + retry_link_bytes

    and with F_s transient faults at transfer site s of payload p_s the
    overhead is exactly ``retry_link_bytes == Σ_s F_s · p_s``.
  * Checksums — ``host_checksum`` runs host-side over buffers already
    resident there: 0 extra link bytes, one O(run) host sweep per crossing
    (fault-free overhead is pure host CPU, gated ≤ 1.15x by the
    ``faults/...`` rows of BENCH_ooc.json).
  * Checkpoints — round-granular checkpoints publish *host-resident* runs
    to disk: 0 extra link bytes (``rounds_checkpointed`` rounds pay
    ≈ Σ run bytes + manifest to the store, not to the device link).
  * Degradation ladder — slab and kway rungs re-plan the *same* round, so a
    completed round still moves exactly ``2·N·(b+v)`` clean link bytes (the
    aborted round's partial crossings fold into ``retry_link_bytes``);
    re-chunking restarts the chunk phase, whose aborted crossings fold in
    the same way.  Every rung is re-validated against
    ``spill_budget_bytes``, so ``device_high_water_bytes`` stays gated.
  * Census — ``guarded`` is host code around the same jitted callables and
    a retry re-invokes the same compiled function, so the per-round /
    per-slab-sweep launch census is identical with and without a policy.
"""
from repro.kernels.histogram import radix_histogram
from repro.kernels.multisplit import tile_multisplit, tile_multisplit_kv
from repro.kernels.bitonic import (bitonic_sort_rows, bitonic_sort_rows_kv,
                                   bitonic_sort_rows_stable)
from repro.kernels.assigned import assigned_histogram
from repro.kernels.fused import (fused_counting_pass, initial_histogram,
                                 make_ping_pong, pad_length)
from repro.kernels.merge import (host_coranks, kway_merge_round,
                                 merge_path_partition, num_merge_rounds,
                                 spill_group_plan)
from repro.kernels.ops import (apply_run_copies, kernel_local_sort,
                               local_sort_class_plan, segmented_local_sort,
                               tile_histogram_pass)

__all__ = [
    "radix_histogram", "tile_multisplit", "tile_multisplit_kv",
    "bitonic_sort_rows", "bitonic_sort_rows_kv", "bitonic_sort_rows_stable",
    "assigned_histogram",
    "fused_counting_pass", "initial_histogram", "make_ping_pong", "pad_length",
    "host_coranks", "kway_merge_round", "merge_path_partition",
    "num_merge_rounds", "spill_group_plan",
    "apply_run_copies", "kernel_local_sort", "local_sort_class_plan",
    "segmented_local_sort", "tile_histogram_pass",
]
