"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors one kernel's contract exactly; tests sweep shapes and
dtypes asserting allclose/array_equal between kernel (interpret mode) and ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def radix_histogram_ref(keys: jnp.ndarray, shift: int, width: int) -> jnp.ndarray:
    """(T, KPB) uint keys -> (T, 2^width) int32 per-tile digit histograms."""
    r = 1 << width
    digit = ((keys >> jnp.array(shift, keys.dtype)) &
             jnp.array(r - 1, keys.dtype)).astype(jnp.int32)

    def row(d):
        return jnp.zeros((r,), jnp.int32).at[d].add(1)

    return jax.vmap(row)(digit)


def tile_multisplit_ref(keys: jnp.ndarray, shift: int, width: int):
    """(T, KPB) keys -> (keys digit-major within each tile, per-tile in-digit
    rank of each *output* slot, per-tile histogram).

    The within-tile permutation is the paper's shared-memory write combining
    (Fig. 3): after it, every digit's keys are one contiguous run, so the HBM
    write of a run is a single coalesced copy.
    """
    r = 1 << width
    digit = ((keys >> jnp.array(shift, keys.dtype)) &
             jnp.array(r - 1, keys.dtype)).astype(jnp.int32)

    def row(krow, drow):
        order = jnp.argsort(drow, stable=True)
        return krow[order], drow[order]

    sorted_keys, sorted_digit = jax.vmap(row)(keys, digit)
    hist = radix_histogram_ref(keys, shift, width)
    excl = jnp.cumsum(hist, axis=1) - hist
    # rank of each output slot within its digit run
    pos = jnp.arange(keys.shape[1], dtype=jnp.int32)[None, :]
    rank = pos - jnp.take_along_axis(excl, sorted_digit, axis=1)
    return sorted_keys, sorted_digit, rank, hist


def bitonic_sort_rows_ref(keys: jnp.ndarray, values: jnp.ndarray | None = None):
    """(S, L) -> rows sorted ascending; values permuted alongside."""
    if values is None:
        return jnp.sort(keys, axis=1)
    order = jnp.argsort(keys, axis=1, stable=True)
    return (jnp.take_along_axis(keys, order, axis=1),
            jnp.take_along_axis(values, order, axis=1))


def onehot_matmul_hist_ref(keys: jnp.ndarray, shift: int, width: int):
    """Flat histogram over all tiles (what the atomics-only GPU kernel makes)."""
    return radix_histogram_ref(keys, shift, width).sum(axis=0)
