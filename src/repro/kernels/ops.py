"""Jit'd composition of the Pallas kernels into full counting-sort passes.

``kernel_counting_pass`` is the on-TPU engine for one partitioning pass
(histogram kernel -> global scan -> multisplit kernel -> coalesced run
copies); the jnp drivers in ``repro.core`` compute the identical permutation
and serve as its oracle.  On this CPU container the kernels run in interpret
mode; on real hardware the same code lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.histogram import radix_histogram
from repro.kernels.multisplit import tile_multisplit
from repro.kernels.bitonic import bitonic_sort_rows, bitonic_sort_rows_kv


@functools.partial(jax.jit, static_argnames=("shift", "width", "kpb",
                                             "key_bits", "interpret"))
def kernel_counting_pass(keys: jnp.ndarray, shift: int, width: int,
                         key_bits: int, kpb: int = 1024,
                         interpret: bool = True) -> jnp.ndarray:
    """One full stable counting-sort pass of a single bucket, kernel-engined.

    Pads to tile granularity with all-ones sentinels (they extract digit r-1
    and are stably last, so they land in the trailing pad slots and slicing
    [:n] recovers the real partition).
    """
    n = keys.shape[0]
    pad = (-n) % kpb
    sentinel = ~jnp.zeros((), keys.dtype)
    padded = jnp.concatenate([keys, jnp.full((pad,), sentinel, keys.dtype)])
    tiles = padded.reshape(-1, kpb)
    t = tiles.shape[0]
    r = 1 << width

    sorted_tiles, sorted_digit, rank, hist = tile_multisplit(
        tiles, shift, width, key_bits, interpret=interpret)

    # global offsets: digit-major across the whole array, tile-major within
    # a digit (the scan the paper stores block histograms for, M3)
    total = hist.sum(axis=0)                                  # (r,)
    digit_base = jnp.cumsum(total) - total                    # (r,)
    tile_carry = jnp.cumsum(hist, axis=0) - hist              # (t, r)
    base = digit_base[None, :] + tile_carry                   # (t, r)

    # destination of output slot (t, j): start of its run + in-run rank.
    # On TPU this is r coalesced run copies per tile; XLA scatter here.
    run_start = jnp.take_along_axis(base, sorted_digit, axis=1)
    dest = run_start + rank
    out = jnp.zeros((t * kpb,), keys.dtype).at[dest.reshape(-1)].set(
        sorted_tiles.reshape(-1))
    return out[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_local_sort(keys: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Local sort of (S, L) padded buckets via the bitonic kernel."""
    return bitonic_sort_rows(keys, interpret=interpret)


def tile_histogram_pass(keys: jnp.ndarray, shift: int, width: int,
                        kpb: int = 8192, interpret: bool = True):
    """Histogram step of a pass: (n,) keys -> ((T, r) tile hists, (r,) total)."""
    n = keys.shape[0]
    pad = (-n) % kpb
    sentinel = ~jnp.zeros((), keys.dtype)
    padded = jnp.concatenate([keys, jnp.full((pad,), sentinel, keys.dtype)])
    hist = radix_histogram(padded.reshape(-1, kpb), shift, width,
                           interpret=interpret)
    total = hist.sum(axis=0)
    if pad:
        total = total.at[(1 << width) - 1].add(-pad)
    return hist, total
