"""Jit'd composition of the non-fused Pallas kernels (local sort + histogram).

The counting passes themselves live in ``repro.kernels.fused`` — one fused
launch per pass (§4.3–§4.4) driven by ``repro.core.plan`` — which retired the
per-bucket multi-launch drivers (``kernel_counting_pass`` /
``segmented_kernel_pass`` and friends) that previously composed the
``tile_multisplit`` kernels here.  What remains are the pieces used outside
the fused pass:

  * ``apply_run_copies``      — the run-copy consumption idiom,
  * ``segmented_local_sort``  — finish done buckets via the stable bitonic
                                kernel (R1: one read + one write),
  * ``kernel_local_sort``     — plain padded-row bitonic driver,
  * ``tile_histogram_pass``   — standalone histogram sweep (benchmarks /
                                doctest; the fused engine only needs it via
                                ``fused.initial_histogram``).

On this CPU container the kernels run in interpret mode; on real hardware the
same code lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.histogram import radix_histogram
from repro.kernels.bitonic import bitonic_sort_rows, bitonic_sort_rows_stable


def apply_run_copies(src: jnp.ndarray, dst: jnp.ndarray, tree):
    """Apply (src, dst) run-copy pairs to a pytree of per-key arrays.

    The single idiom for consuming ``segmented_local_sort`` output: invalid
    lanes carry ``src == n``/``dst == n`` (clipped on gather, dropped on
    scatter), and untouched slots keep their old contents — done buckets
    persist in place for free.
    """
    n = jax.tree.leaves(tree)[0].shape[0]
    safe_src = jnp.clip(src, 0, n - 1)
    return jax.tree.map(
        lambda v: v.at[dst].set(v[safe_src], mode="drop"), tree)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_local_sort(keys: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Local sort of (S, L) padded buckets via the bitonic kernel."""
    return bitonic_sort_rows(keys, interpret=interpret)


def local_sort_class_plan(n: int, row_len: int, s_max: int,
                          min_len: int = 32):
    """Power-of-two size classes for the local sort (§4.2's *local sort
    configurations*): ``((L_0, rows_0), (L_1, rows_1), ...)``.

    Class widths double from ``min_len`` up to ``row_len``; a bucket of size
    s sorts in the narrowest class with ``L >= s`` (class 0 additionally
    catches every bucket ``<= min_len``), so tiny done-buckets stop paying
    ∂̂-sized padding.  Row capacities are static counting bounds: a bucket in
    class i > 0 holds more than ``L_i/2`` keys, so at most
    ``n // (L_i/2 + 1) + 1`` such buckets exist; class 0 is bounded only by
    the total bucket count ``s_max``.  The capacities are what make one
    fixed-shape ``_rows_call`` per class possible under XLA's static shapes.
    """
    row_len = max(1, row_len)
    l = min(row_len, max(1, min_len))
    classes = [(l, max(1, s_max))]
    while l < row_len:
        l *= 2
        cap = n // (l // 2 + 1) + 1
        classes.append((l, max(1, min(s_max, cap))))
    return tuple(classes)


@functools.partial(jax.jit, static_argnames=("row_len", "interpret",
                                             "classes"))
def segmented_local_sort(keys: jnp.ndarray, seg_start: jnp.ndarray,
                         seg_size: jnp.ndarray, seg_sortable: jnp.ndarray,
                         row_len: int, interpret: bool = True,
                         classes=None):
    """Finish flagged buckets in one read+write via the stable bitonic kernel.

    Gathers each flagged segment into a sentinel-padded row, sorts rows by
    (key, global index) — so pads (index n) lose every tie and the order is
    stable — and returns (src, dst) run copies that place the sorted prefix
    back over the segment.  Unflagged segments are untouched (their lanes
    return ``dst == n``).

    ``classes`` is an optional size-class plan (``local_sort_class_plan``):
    segments are binned into power-of-two row widths — one fixed-shape
    bitonic launch per class — so a 3-key bucket sorts in a ``min_len`` row
    instead of a ``row_len`` one.  ``None`` keeps the single worst-case
    table: one class of width ``row_len`` with a row per segment slot.
    """
    n = keys.shape[0]
    s = seg_start.shape[0]
    if classes is None:
        classes = ((row_len, s),)
    sentinel = ~jnp.zeros((), keys.dtype)
    srcs, dsts = [], []
    prev_l = -1                    # class 0 catches every size <= its width
    for l, rows in classes:
        in_cls = seg_sortable & (seg_size <= l) & (seg_size > prev_l)
        rsel = jnp.nonzero(in_cls, size=min(rows, s), fill_value=s)[0]
        sel = jnp.clip(rsel, 0, s - 1)
        valid = rsel < s
        starts_c = jnp.where(valid, seg_start[sel], n)
        sizes_c = jnp.where(valid, seg_size[sel], 0)

        lane = jnp.arange(l, dtype=jnp.int32)
        gidx = starts_c[:, None] + lane[None, :]              # (rows, L)
        lv = lane[None, :] < sizes_c[:, None]
        safe = jnp.clip(gidx, 0, max(n - 1, 0))
        row_keys = jnp.where(lv, keys[safe], sentinel)
        idx = jnp.where(lv, gidx, n).astype(jnp.int32)

        _, si = bitonic_sort_rows_stable(row_keys, idx, interpret=interpret)

        # valid lanes form each row's prefix both before and after the sort
        dst = jnp.where(lv, gidx, n)
        srcs.append(si.reshape(-1))
        dsts.append(dst.reshape(-1))
        prev_l = l
    return jnp.concatenate(srcs), jnp.concatenate(dsts)


def tile_histogram_pass(keys: jnp.ndarray, shift: int, width: int,
                        kpb: int = 8192, interpret: bool = True):
    """Histogram step of a pass: (n,) keys -> ((T, r) tile hists, (r,) total).

    Example — count the top-byte digits of two u32 keys (the trailing
    sentinel padding is removed from ``total``)::

        >>> import numpy as np, jax.numpy as jnp
        >>> from repro.kernels import tile_histogram_pass
        >>> x = jnp.asarray(np.array([0x01020304, 0xFF000000], np.uint32))
        >>> hist, total = tile_histogram_pass(x, shift=24, width=8, kpb=8)
        >>> int(total[0x01]), int(total[0xFF]), int(total.sum())
        (1, 1, 2)
    """
    n = keys.shape[0]
    pad = (-n) % kpb
    sentinel = ~jnp.zeros((), keys.dtype)
    padded = jnp.concatenate([keys, jnp.full((pad,), sentinel, keys.dtype)])
    hist = radix_histogram(padded.reshape(-1, kpb), shift, width,
                           interpret=interpret)
    total = hist.sum(axis=0)
    if pad:
        total = total.at[(1 << width) - 1].add(-pad)
    return hist, total
