"""Jit'd composition of the Pallas kernels into full counting-sort passes.

``kernel_counting_pass`` is the on-TPU engine for one partitioning pass of a
single bucket (histogram kernel -> global scan -> multisplit kernel ->
coalesced run copies); ``segmented_kernel_pass`` is the same pipeline driven
by block-assignment descriptors (§4.2) so every active bucket of an MSD pass
is processed by one constant-size launch; ``segmented_local_sort`` finishes
done buckets with the stable bitonic kernel.  The jnp drivers in
``repro.core`` compute the identical permutations and serve as oracles.  On
this CPU container the kernels run in interpret mode; on real hardware the
same code lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.histogram import radix_histogram
from repro.kernels.multisplit import tile_multisplit, tile_multisplit_kv
from repro.kernels.bitonic import (bitonic_sort_rows, bitonic_sort_rows_kv,
                                   bitonic_sort_rows_stable)
from repro.kernels.assigned import make_block_assignments


def apply_run_copies(src: jnp.ndarray, dst: jnp.ndarray, tree):
    """Apply (src, dst) run-copy pairs to a pytree of per-key arrays.

    The single idiom for consuming ``kernel_pass_perm``/
    ``segmented_kernel_pass``/``segmented_local_sort`` output: invalid lanes
    carry ``src == n``/``dst == n`` (clipped on gather, dropped on scatter),
    and untouched slots keep their old contents — done buckets persist in
    place for free.
    """
    n = jax.tree.leaves(tree)[0].shape[0]
    safe_src = jnp.clip(src, 0, n - 1)
    return jax.tree.map(
        lambda v: v.at[dst].set(v[safe_src], mode="drop"), tree)


def _global_run_starts(hist: jnp.ndarray) -> jnp.ndarray:
    """(T, r) tile histograms -> (T, r) global run starts: digit-major across
    the whole array, tile-major within a digit (the scan the paper stores
    block histograms for, M3)."""
    total = hist.sum(axis=0)                                  # (r,)
    digit_base = jnp.cumsum(total) - total                    # (r,)
    tile_carry = jnp.cumsum(hist, axis=0) - hist              # (T, r)
    return digit_base[None, :] + tile_carry


@functools.partial(jax.jit, static_argnames=("shift", "width", "kpb",
                                             "key_bits", "interpret"))
def kernel_counting_pass(keys: jnp.ndarray, shift: int, width: int,
                         key_bits: int, kpb: int = 1024,
                         interpret: bool = True) -> jnp.ndarray:
    """One full stable counting-sort pass of a single bucket, kernel-engined.

    Pads to tile granularity with all-ones sentinels (they extract digit r-1
    and are stably last, so they land in the trailing pad slots and slicing
    [:n] recovers the real partition).
    """
    n = keys.shape[0]
    pad = (-n) % kpb
    sentinel = ~jnp.zeros((), keys.dtype)
    padded = jnp.concatenate([keys, jnp.full((pad,), sentinel, keys.dtype)])
    tiles = padded.reshape(-1, kpb)
    t = tiles.shape[0]

    sorted_tiles, sorted_digit, rank, hist = tile_multisplit(
        tiles, shift, width, key_bits, interpret=interpret)

    # destination of output slot (t, j): start of its run + in-run rank.
    # On TPU this is r coalesced run copies per tile; XLA scatter here.
    run_start = jnp.take_along_axis(_global_run_starts(hist), sorted_digit,
                                    axis=1)
    dest = run_start + rank
    out = jnp.zeros((t * kpb,), keys.dtype).at[dest.reshape(-1)].set(
        sorted_tiles.reshape(-1))
    return out[:n]


@functools.partial(jax.jit, static_argnames=("shift", "width", "kpb",
                                             "key_bits", "interpret"))
def kernel_counting_pass_kv(keys: jnp.ndarray, vals: jnp.ndarray, shift: int,
                            width: int, key_bits: int, kpb: int = 1024,
                            interpret: bool = True):
    """Key-value counting-sort pass: values ride the in-VMEM permutation of
    ``tile_multisplit_kv`` (§4.6) and the same run copies as the keys.

    ``vals`` must be a 32/64-bit integer array (the decomposed-pair layout);
    arbitrary payloads go through ``kernel_pass_perm`` instead.
    """
    n = keys.shape[0]
    pad = (-n) % kpb
    sentinel = ~jnp.zeros((), keys.dtype)
    padded = jnp.concatenate([keys, jnp.full((pad,), sentinel, keys.dtype)])
    vpad = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    tiles = padded.reshape(-1, kpb)
    vtiles = vpad.reshape(-1, kpb)
    t = tiles.shape[0]
    val_bits = vals.dtype.itemsize * 8

    sk, sv, sd, rank, hist = tile_multisplit_kv(
        tiles, vtiles, shift, width, key_bits, val_bits, interpret=interpret)

    run_start = jnp.take_along_axis(_global_run_starts(hist), sd, axis=1)
    dest = (run_start + rank).reshape(-1)
    out_k = jnp.zeros((t * kpb,), keys.dtype).at[dest].set(sk.reshape(-1))
    out_v = jnp.zeros((t * kpb,), vals.dtype).at[dest].set(sv.reshape(-1))
    return out_k[:n], out_v[:n]


@functools.partial(jax.jit, static_argnames=("shift", "width", "kpb",
                                             "key_bits", "interpret"))
def kernel_pass_perm(keys: jnp.ndarray, shift: int, width: int, key_bits: int,
                     kpb: int = 1024, interpret: bool = True):
    """Permutation form of a counting pass: (src, dst) run-copy index pairs.

    The multisplit carries each key's *global input index* as its payload, so
    the pass's permutation comes back explicitly and a driver can move any
    pytree of payloads with one gather + scatter per leaf:
    ``new = old.at[dst].set(old[src], mode="drop")``.  Pad lanes return
    ``src == n`` and ``dst == n`` (dropped).
    """
    n = keys.shape[0]
    pad = (-n) % kpb
    sentinel = ~jnp.zeros((), keys.dtype)
    padded = jnp.concatenate([keys, jnp.full((pad,), sentinel, keys.dtype)])
    idx = jnp.concatenate([jnp.arange(n, dtype=jnp.int32),
                           jnp.full((pad,), n, jnp.int32)])
    tiles = padded.reshape(-1, kpb)

    _, sv, sd, rank, hist = tile_multisplit_kv(
        tiles, idx.reshape(-1, kpb), shift, width, key_bits, 32,
        interpret=interpret)

    run_start = jnp.take_along_axis(_global_run_starts(hist), sd, axis=1)
    src = sv.reshape(-1)
    dst = jnp.where(src < n, (run_start + rank).reshape(-1), n)
    return src, dst


@functools.partial(jax.jit, static_argnames=("width", "kpb", "g_max",
                                             "interpret"))
def segmented_kernel_pass(keys: jnp.ndarray, seg_base: jnp.ndarray,
                          seg_size: jnp.ndarray, width: int, kpb: int,
                          g_max: int, interpret: bool = True):
    """One counting pass over *all active buckets at once* (§4.2–§4.4).

    Block-assignment descriptors chop every segment ([seg_base, seg_base +
    seg_size) slices of ``keys``) into KPB-sized blocks; one constant-size
    multisplit launch partitions all blocks; per-segment scans of the block
    histograms turn in-run ranks into absolute destinations *inside each
    segment* — the in-place partition of an MSD pass.

    ``keys`` must already expose the pass's digit in their low ``width`` bits
    (the MSD driver pre-shifts, keeping the kernel's digit extraction static).

    Returns ``(src, dst, seg_hist)``: run-copy index pairs (invalid lanes get
    ``src == n``/``dst == n``, to be dropped) and the (A, r) per-segment
    histogram for the driver's bucket bookkeeping (M2).
    """
    n = keys.shape[0]
    a_max = seg_base.shape[0]
    r = 1 << width
    key_bits = keys.dtype.itemsize * 8

    ba = make_block_assignments(seg_base, seg_size, kpb, g_max)
    lane = jnp.arange(kpb, dtype=jnp.int32)
    gidx = ba.key_offset[:, None] + lane[None, :]             # (G, KPB)
    seg_safe = jnp.clip(ba.seg_idx, 0, a_max - 1)
    in_seg = ba.blk_in_seg[:, None] * kpb + lane[None, :]
    lane_valid = ba.valid[:, None] & (in_seg < seg_size[seg_safe][:, None])
    safe = jnp.clip(gidx, 0, max(n - 1, 0))
    sentinel = ~jnp.zeros((), keys.dtype)
    blocks = jnp.where(lane_valid, keys[safe], sentinel)      # pad digit = r-1
    idx_blocks = jnp.where(lane_valid, gidx, n).astype(jnp.int32)

    _, sv, sd, rank, hist = tile_multisplit_kv(
        blocks, idx_blocks, 0, width, key_bits, 32, interpret=interpret)

    # remove the sentinel pads from the histograms (they are stably last in
    # each block's digit-(r-1) run, so real ranks are unaffected)
    pads = kpb - lane_valid.sum(axis=1, dtype=jnp.int32)
    hv = hist.at[:, r - 1].add(-pads) * ba.valid[:, None].astype(jnp.int32)

    # per-segment offsets: M2 bucket histogram + digit-major exclusive scan
    # inside the segment, M3 block-carry across the segment's blocks
    seg_hist = jnp.zeros((a_max, r), jnp.int32).at[ba.seg_idx].add(
        hv, mode="drop")
    seg_excl = jnp.cumsum(seg_hist, axis=1) - seg_hist        # (A, r)
    gexcl = jnp.cumsum(hv, axis=0) - hv                       # (G, r)
    carry = gexcl - gexcl[jnp.clip(ba.first_block, 0, g_max - 1)]
    base = seg_base[seg_safe][:, None] + seg_excl[seg_safe] + carry

    run_start = jnp.take_along_axis(base, sd, axis=1)
    src = sv.reshape(-1)                                      # pads carry n
    dst = jnp.where(src < n, (run_start + rank).reshape(-1), n)
    return src, dst, seg_hist


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_local_sort(keys: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Local sort of (S, L) padded buckets via the bitonic kernel."""
    return bitonic_sort_rows(keys, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("row_len", "interpret"))
def segmented_local_sort(keys: jnp.ndarray, seg_start: jnp.ndarray,
                         seg_size: jnp.ndarray, seg_sortable: jnp.ndarray,
                         row_len: int, interpret: bool = True):
    """Finish flagged buckets in one read+write via the stable bitonic kernel.

    Gathers each flagged segment into a sentinel-padded (S, L) row, sorts rows
    by (key, global index) — so pads (index n) lose every tie and the order is
    stable — and returns (src, dst) run copies that place the sorted prefix
    back over the segment.  Unflagged segments are untouched (their lanes
    return ``dst == n``).
    """
    n = keys.shape[0]
    lane = jnp.arange(row_len, dtype=jnp.int32)
    gidx = seg_start[:, None] + lane[None, :]                 # (S, L)
    lv = seg_sortable[:, None] & (lane[None, :] < seg_size[:, None])
    safe = jnp.clip(gidx, 0, max(n - 1, 0))
    sentinel = ~jnp.zeros((), keys.dtype)
    rows = jnp.where(lv, keys[safe], sentinel)
    idx = jnp.where(lv, gidx, n).astype(jnp.int32)

    _, si = bitonic_sort_rows_stable(rows, idx, interpret=interpret)

    # valid lanes form each row's prefix both before and after the sort
    dst = jnp.where(lv, gidx, n)
    return si.reshape(-1), dst.reshape(-1)


def tile_histogram_pass(keys: jnp.ndarray, shift: int, width: int,
                        kpb: int = 8192, interpret: bool = True):
    """Histogram step of a pass: (n,) keys -> ((T, r) tile hists, (r,) total).

    Example — count the top-byte digits of two u32 keys (the trailing
    sentinel padding is removed from ``total``)::

        >>> import numpy as np, jax.numpy as jnp
        >>> from repro.kernels import tile_histogram_pass
        >>> x = jnp.asarray(np.array([0x01020304, 0xFF000000], np.uint32))
        >>> hist, total = tile_histogram_pass(x, shift=24, width=8, kpb=8)
        >>> int(total[0x01]), int(total[0xFF]), int(total.sum())
        (1, 1, 2)
    """
    n = keys.shape[0]
    pad = (-n) % kpb
    sentinel = ~jnp.zeros((), keys.dtype)
    padded = jnp.concatenate([keys, jnp.full((pad,), sentinel, keys.dtype)])
    hist = radix_histogram(padded.reshape(-1, kpb), shift, width,
                           interpret=interpret)
    total = hist.sum(axis=0)
    if pad:
        total = total.at[(1 << width) - 1].add(-pad)
    return hist, total
