"""Jit'd composition of the non-fused Pallas kernels (local sort + histogram).

The counting passes themselves live in ``repro.kernels.fused`` — one fused
launch per pass (§4.3–§4.4) driven by ``repro.core.plan`` — which retired the
per-bucket multi-launch drivers (``kernel_counting_pass`` /
``segmented_kernel_pass`` and friends) that previously composed the
``tile_multisplit`` kernels here.  What remains are the pieces used outside
the fused pass:

  * ``apply_run_copies``      — the run-copy consumption idiom,
  * ``segmented_local_sort``  — finish done buckets via the stable bitonic
                                kernel (R1: one read + one write),
  * ``kernel_local_sort``     — plain padded-row bitonic driver,
  * ``tile_histogram_pass``   — standalone histogram sweep (benchmarks /
                                doctest; the fused engine only needs it via
                                ``fused.initial_histogram``).

On this CPU container the kernels run in interpret mode; on real hardware the
same code lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.histogram import radix_histogram
from repro.kernels.bitonic import bitonic_sort_rows, bitonic_sort_rows_stable


def apply_run_copies(src: jnp.ndarray, dst: jnp.ndarray, tree):
    """Apply (src, dst) run-copy pairs to a pytree of per-key arrays.

    The single idiom for consuming ``segmented_local_sort`` output: invalid
    lanes carry ``src == n``/``dst == n`` (clipped on gather, dropped on
    scatter), and untouched slots keep their old contents — done buckets
    persist in place for free.
    """
    n = jax.tree.leaves(tree)[0].shape[0]
    safe_src = jnp.clip(src, 0, n - 1)
    return jax.tree.map(
        lambda v: v.at[dst].set(v[safe_src], mode="drop"), tree)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_local_sort(keys: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Local sort of (S, L) padded buckets via the bitonic kernel."""
    return bitonic_sort_rows(keys, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("row_len", "interpret"))
def segmented_local_sort(keys: jnp.ndarray, seg_start: jnp.ndarray,
                         seg_size: jnp.ndarray, seg_sortable: jnp.ndarray,
                         row_len: int, interpret: bool = True):
    """Finish flagged buckets in one read+write via the stable bitonic kernel.

    Gathers each flagged segment into a sentinel-padded (S, L) row, sorts rows
    by (key, global index) — so pads (index n) lose every tie and the order is
    stable — and returns (src, dst) run copies that place the sorted prefix
    back over the segment.  Unflagged segments are untouched (their lanes
    return ``dst == n``).
    """
    n = keys.shape[0]
    lane = jnp.arange(row_len, dtype=jnp.int32)
    gidx = seg_start[:, None] + lane[None, :]                 # (S, L)
    lv = seg_sortable[:, None] & (lane[None, :] < seg_size[:, None])
    safe = jnp.clip(gidx, 0, max(n - 1, 0))
    sentinel = ~jnp.zeros((), keys.dtype)
    rows = jnp.where(lv, keys[safe], sentinel)
    idx = jnp.where(lv, gidx, n).astype(jnp.int32)

    _, si = bitonic_sort_rows_stable(rows, idx, interpret=interpret)

    # valid lanes form each row's prefix both before and after the sort
    dst = jnp.where(lv, gidx, n)
    return si.reshape(-1), dst.reshape(-1)


def tile_histogram_pass(keys: jnp.ndarray, shift: int, width: int,
                        kpb: int = 8192, interpret: bool = True):
    """Histogram step of a pass: (n,) keys -> ((T, r) tile hists, (r,) total).

    Example — count the top-byte digits of two u32 keys (the trailing
    sentinel padding is removed from ``total``)::

        >>> import numpy as np, jax.numpy as jnp
        >>> from repro.kernels import tile_histogram_pass
        >>> x = jnp.asarray(np.array([0x01020304, 0xFF000000], np.uint32))
        >>> hist, total = tile_histogram_pass(x, shift=24, width=8, kpb=8)
        >>> int(total[0x01]), int(total[0xFF]), int(total.sum())
        (1, 1, 2)
    """
    n = keys.shape[0]
    pad = (-n) % kpb
    sentinel = ~jnp.zeros((), keys.dtype)
    padded = jnp.concatenate([keys, jnp.full((pad,), sentinel, keys.dtype)])
    hist = radix_histogram(padded.reshape(-1, kpb), shift, width,
                           interpret=interpret)
    total = hist.sum(axis=0)
    if pad:
        total = total.at[(1 << width) - 1].add(-pad)
    return hist, total
