"""Pallas merge-path kernel: ONE launch per k-way run-merge round (§5).

The out-of-core pipeline (``core.outofcore``) sorts device-sized chunks with
the fused counting-pass engine and then merges the resulting sorted runs.
This module is the device half of that merge: a merge-path k-way merge in the
style of Casanova et al. (*An Efficient Multiway Mergesort for GPU
Architectures*), expressed with the same constant-grid / scalar-prefetch
discipline as ``kernels.fused``:

  * runs live contiguously in a flat ping-pong buffer; a merge *round* fuses
    groups of up to K adjacent runs into one run each, all groups in ONE
    Pallas launch (one ``pallas_call`` per round — the census invariant),
  * the output is chopped into fixed-size tiles; for every tile boundary the
    *diagonal partition* (the k-dimensional co-rank split of the merged
    prefix across the K runs, ties broken by run index then position — the
    merge path) is computed by a sort-free bitwise binary search
    (``merge_path_partition``) and scalar-prefetched as window tables,
  * each grid step loads one tile-sized window per run at a dynamic offset,
    ranks the union in-VMEM (per-run-pair ``searchsorted`` co-ranks — the
    tile-local merge, O(K²·T·log T); the all-pairs counting rank it replaced
    is kept as ``rank="counting"``, the byte-parity oracle), and scatters
    keys and value slabs as coalesced per-tile runs into the donated
    alternate buffer; masked lanes land in the trash slot ``n``.

The partition math also comes in a host-side numpy flavour
(:func:`host_coranks`, :func:`spill_group_plan`) for the out-of-core spill
path: when runs live host-side between rounds, the merge path is computable
from O(bits · K · log L) probed elements per diagonal without materialising
anything on device, and a group cuts into slab-sized strips of whole output
tiles —
each strip one bounded upload + ONE kernel launch + one download.

Stability: ties are broken (key, run index, in-run position), so a round is
stable with respect to run order — runs of equal keys keep their chunk order,
which is what makes ``oocsort`` deterministic across any chunking.

No comparison sorts anywhere: the diagonal search is ``jnp.searchsorted``
(binary-search scan) and the in-tile rank is built from binary searches too,
so the merge phase traces to zero (stable)HLO ``sort`` ops — certified by
the oocsort test wall alongside the one-launch-per-round census.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def merge_groups(lens, kway: int):
    """Group adjacent runs for one round: [[len, ...], ...] of <= kway runs."""
    lens = list(lens)
    return [lens[i:i + kway] for i in range(0, len(lens), kway)]


def num_merge_rounds(num_runs: int, kway: int) -> int:
    """⌈log_kway(num_runs)⌉ — rounds until a single run remains."""
    rounds = 0
    while num_runs > 1:
        num_runs = -(-num_runs // kway)
        rounds += 1
    return rounds


def _coranks(grp: jnp.ndarray, glens, diags) -> jnp.ndarray:
    """Diagonal partition of K sorted runs at every requested diagonal.

    ``grp`` is (K, Lmax) sorted unsigned keys (rows sentinel-padded past
    their static lengths ``glens``); ``diags`` is a static array of merged
    prefix lengths m.  Returns (D, K) co-ranks c with ``sum(c[i]) == m[i]``
    and the selected elements exactly the m smallest under (key, run,
    position) order — the k-way merge path, found by building the m-th order
    statistic's key bit-by-bit (MSB down) with per-run binary searches.
    """
    kdt = grp.dtype
    kbits = jnp.iinfo(kdt).bits
    lens_a = jnp.asarray(glens, jnp.int32)
    m = jnp.asarray(diags, jnp.int32)
    one = jnp.ones((), kdt)

    def count(v, side):  # (D,) key bound -> (D, K) per-run counts, pad-free
        c = jax.vmap(lambda row: jnp.searchsorted(row, v, side=side),
                     in_axes=0, out_axes=1)(grp)
        return jnp.minimum(c.astype(jnp.int32), lens_a[None, :])

    # v* = smallest key with #(keys <= v*) >= m: greedy MSB-down, keeping a
    # candidate bit whenever even all keys strictly below it fall short of m
    v = jnp.zeros(m.shape, kdt)
    for b in reversed(range(kbits)):
        cand = v | (one << b)
        below = count(cand - one, "right").sum(axis=1)
        v = jnp.where(below < m, cand, v)

    lb = count(v, "left")                      # keys <  v* per run
    ties = count(v, "right") - lb              # keys == v* per run
    # distribute the remaining slots among the v*-ties in run order
    rem = (m - lb.sum(axis=1))[:, None]
    excl = jnp.cumsum(ties, axis=1) - ties
    return lb + jnp.clip(rem - excl, 0, ties)


def merge_path_partition(keys: jnp.ndarray, lens, kway: int, tpb: int):
    """Tile descriptor tables for one merge round over ``keys``.

    ``keys`` is the flat run buffer (sorted unsigned runs back to back,
    padding beyond ``sum(lens)``), ``lens`` the static per-run lengths.
    Output runs occupy exactly the concatenated span of their group, so the
    merged buffer keeps the same layout with coarser boundaries.

    Returns ``(out_off, out_cnt, win_start, win_take)``: per grid step the
    absolute output offset and live lane count (static, (G,) int32), and the
    flattened (G * kway,) per-run window tables — absolute start of the run
    window feeding the tile and how many of its lanes are live.  Runs beyond
    a group's width get ``start = n`` (the trash-adjacent pad region) and
    ``take = 0``; single-run groups degenerate to a copy-through partition.
    """
    offs = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    n = int(offs[-1])
    out_off, out_cnt = [], []
    ws_parts, wt_parts = [], []
    g0 = 0
    for glens in merge_groups(lens, kway):
        K = len(glens)
        gbase = int(offs[g0])
        glen = int(sum(glens))
        ntiles = max(1, -(-glen // tpb))
        diags = np.minimum(np.arange(ntiles + 1) * tpb, glen)
        if K == 1:
            cor = jnp.asarray(diags[:, None], jnp.int32)     # trivial path
        else:
            lmax = max(glens)
            sentinel = ~jnp.zeros((), keys.dtype)
            rows = [
                jnp.concatenate(
                    [keys[int(offs[g0 + r]):int(offs[g0 + r]) + glens[r]],
                     jnp.full((lmax - glens[r],), sentinel, keys.dtype)])
                for r in range(K)]
            cor = _coranks(jnp.stack(rows), glens, diags)
        run_base = jnp.asarray([int(offs[g0 + r]) for r in range(K)],
                               jnp.int32)
        start = cor[:-1] + run_base[None, :]                 # (T, K)
        take = cor[1:] - cor[:-1]
        pad = kway - K
        if pad:
            start = jnp.concatenate(
                [start, jnp.full((ntiles, pad), n, jnp.int32)], axis=1)
            take = jnp.concatenate(
                [take, jnp.zeros((ntiles, pad), jnp.int32)], axis=1)
        ws_parts.append(start)
        wt_parts.append(take)
        out_off.extend((gbase + diags[:-1]).tolist())
        out_cnt.extend((diags[1:] - diags[:-1]).tolist())
        g0 += K
    return (jnp.asarray(out_off, jnp.int32), jnp.asarray(out_cnt, jnp.int32),
            jnp.concatenate(ws_parts).reshape(-1).astype(jnp.int32),
            jnp.concatenate(wt_parts).reshape(-1).astype(jnp.int32))


# --------- host-side partition math (the out-of-core spill path) ------------

def host_coranks(runs, diags) -> np.ndarray:
    """NumPy mirror of :func:`_coranks` over host-resident runs.

    ``runs`` is a list of 1-D sorted unsigned numpy arrays (no padding —
    ``np.searchsorted`` bounds each row by its own length); ``diags`` the
    merged prefix lengths m.  Returns (D, K) int64 co-ranks with
    ``sum(c[i]) == m[i]`` and the selected elements exactly the m smallest
    under (key, run, position) order.  Each diagonal costs O(bits · K · log L)
    probed elements, so the merge path of host-spilled runs is computable
    without touching more than a window of each run.
    """
    dt = np.dtype(runs[0].dtype)
    bits = np.iinfo(dt).bits
    m = np.asarray(diags, np.int64)

    def count(vals, side):                      # (D,) bounds -> (D, K)
        return np.stack([np.searchsorted(r, vals, side=side)
                         for r in runs], axis=1).astype(np.int64)

    # v* = smallest key with #(keys <= v*) >= m: greedy MSB-down, keeping a
    # candidate bit whenever even all keys strictly below it fall short of m
    v = np.zeros(m.shape, dt)
    for b in reversed(range(bits)):
        cand = v | np.asarray(1 << b, dt)
        below = count(cand, "left").sum(axis=1)
        v = np.where(below < m, cand, v)

    lb = count(v, "left")                       # keys <  v* per run
    ties = count(v, "right") - lb               # keys == v* per run
    # distribute the remaining slots among the v*-ties in run order
    rem = (m - lb.sum(axis=1))[:, None]
    excl = np.cumsum(ties, axis=1) - ties
    return lb + np.clip(rem - excl, 0, ties)


class SpillStrip(NamedTuple):
    """One slab-sized strip of a merge group's output (host-spill path).

    ``win_lo``/``win_len`` select each run's window feeding the strip
    (``sum(win_len) == out_len``); the windows pack back to back into a
    slab and ``tables`` are the slab-local scalar-prefetch descriptors for
    :func:`kway_merge_round` (``n = slab_elems``), zero-count-padded to the
    slab's full ``G = slab_elems // tile`` grid so every strip of a round
    shares one kernel signature.
    """
    out_lo: int                 # group-relative output offset of the strip
    out_len: int                # live output elements (== sum(win_len))
    win_lo: Tuple[int, ...]     # per-run window start within each run
    win_len: Tuple[int, ...]    # per-run window length
    tables: Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


def spill_group_plan(runs, kway: int, tile: int, slab_elems: int):
    """Cut one merge group of host-resident runs into slab-sized strips.

    ``runs`` is a list of <= ``kway`` sorted unsigned numpy runs;
    ``slab_elems`` (a multiple of ``tile``) bounds each strip's output.  The
    group's merge path is solved once at tile granularity by
    :func:`host_coranks`, then sliced into strips of ``slab_elems // tile``
    whole output tiles — so strips tile the group's output exactly once and
    the (key, run, position) tie order is preserved across strip boundaries.
    Returns the list of :class:`SpillStrip` descriptors.
    """
    if slab_elems < tile or slab_elems % tile:
        raise ValueError("slab_elems must be a positive multiple of tile")
    K = len(runs)
    glens = [int(r.shape[0]) for r in runs]
    glen = sum(glens)
    ntiles = max(1, -(-glen // tile))
    diags = np.minimum(np.arange(ntiles + 1, dtype=np.int64) * tile, glen)
    if K == 1:
        cor = diags[:, None]                            # trivial path
    else:
        cor = host_coranks(runs, diags)
    G = slab_elems // tile
    strips = []
    for t0 in range(0, ntiles, G):
        t1 = min(t0 + G, ntiles)
        nt = t1 - t0
        out_lo = int(diags[t0])
        out_len = int(diags[t1] - diags[t0])
        win_lo = tuple(int(cor[t0, r]) for r in range(K))
        win_len = tuple(int(cor[t1, r] - cor[t0, r]) for r in range(K))
        seg = np.concatenate([[0], np.cumsum(win_len)])
        # dead tiles / runs point their window at the slab's pad region
        # (start = slab_elems, take = 0) exactly like merge_path_partition
        out_off = np.zeros(G, np.int32)
        out_cnt = np.zeros(G, np.int32)
        ws = np.full((G, kway), slab_elems, np.int32)
        wt = np.zeros((G, kway), np.int32)
        out_off[:nt] = (diags[t0:t1] - diags[t0]).astype(np.int32)
        out_cnt[:nt] = (diags[t0 + 1:t1 + 1] - diags[t0:t1]).astype(np.int32)
        for r in range(K):
            ws[:nt, r] = (seg[r] + cor[t0:t1, r] - cor[t0, r]).astype(np.int32)
            wt[:nt, r] = (cor[t0 + 1:t1 + 1, r] -
                          cor[t0:t1, r]).astype(np.int32)
        strips.append(SpillStrip(out_lo, out_len, win_lo, win_len,
                                 (out_off, out_cnt, ws.reshape(-1),
                                  wt.reshape(-1))))
    return strips


def _tile_rank(keys, live, takes, *, kway: int, tpb: int, rank: str):
    """Rank of every window element under (key, run, lane) order.

    ``keys``/``live`` are the (kway, tpb) window union; ``takes`` the per-run
    live lane counts (live lanes are a *prefix* of each sorted window).  The
    returned (kway·tpb,) ranks are exact for live elements and arbitrary for
    dead ones (the caller masks them into the trash slot).

    ``rank="searchsorted"`` resolves each element as its own lane index plus
    per-run-pair binary-search co-ranks — O(K²·T·log T): element (r, j) is
    preceded by its own live prefix (j), by every element <= it in runs
    r' < r, and by every element < it in runs r' > r.  ``rank="counting"``
    is the all-pairs comparison rank it replaced — O((K·T)²), kept as the
    byte-parity oracle for the searchsorted path.
    """
    kf = keys.reshape(-1)
    lf = live.reshape(-1)
    flat = jax.lax.iota(jnp.int32, kway * tpb)
    if rank == "counting":
        # the run-major flat index encodes (run, lane), so a single index
        # compare breaks key ties — runs of equal keys keep chunk order
        before = lf[None, :] & ((kf[None, :] < kf[:, None]) |
                                ((kf[None, :] == kf[:, None]) &
                                 (flat[None, :] < flat[:, None])))
        return jnp.sum(before, axis=1, dtype=jnp.int32)
    # dead lanes mask to the all-ones sentinel, so every row stays sorted and
    # binary-searchable; counts clip to the live prefix because a sentinel
    # query would otherwise count the dead lanes as <=-ties
    sentinel = ~jnp.zeros((), keys.dtype)
    win = jnp.where(live, keys, sentinel)
    takes_a = jnp.stack(takes).astype(jnp.int32)

    def counts(side):
        c = jax.vmap(lambda w: jnp.searchsorted(
            w, kf, side=side, method="scan_unrolled"))(win)
        return jnp.minimum(c.astype(jnp.int32), takes_a[:, None])

    below = counts("left")                       # per run: # keys strictly <
    below_eq = counts("right")                   # per run: # keys <=
    run_of = flat // tpb
    rid = jax.lax.iota(jnp.int32, kway)
    contrib = jnp.where(rid[:, None] < run_of[None, :], below_eq,
                        jnp.where(rid[:, None] > run_of[None, :], below, 0))
    return flat % tpb + jnp.sum(contrib, axis=0, dtype=jnp.int32)


def _kway_merge_kernel(off_ref, cnt_ref, wstart_ref, wtake_ref, *refs,
                       kway: int, tpb: int, n: int, num_vals: int, rank: str):
    """One grid step = one output tile of one merge group."""
    srck_ref = refs[0]
    srcv_refs = refs[1:1 + num_vals]
    # refs[1+num_vals : 2+2*num_vals] are the aliased alternate buffers —
    # donation targets only, never read.
    dstk_ref = refs[2 + 2 * num_vals]
    dstv_refs = refs[3 + 2 * num_vals:3 + 3 * num_vals]

    g = pl.program_id(0)
    out_off = off_ref[g]
    cnt = cnt_ref[g]
    lane = jax.lax.iota(jnp.int32, tpb)

    starts = [wstart_ref[g * kway + r] for r in range(kway)]
    takes = [wtake_ref[g * kway + r] for r in range(kway)]
    keys = jnp.stack([srck_ref[pl.ds(starts[r], tpb)] for r in range(kway)])
    live = jnp.stack([lane < takes[r] for r in range(kway)])

    # tile-local merge: element j precedes element i iff
    # (key_j, run_j, lane_j) < (key_i, run_i, lane_i)
    kf = keys.reshape(-1)
    lf = live.reshape(-1)
    ranks = _tile_rank(keys, live, takes, kway=kway, tpb=tpb, rank=rank)

    # coalesced per-tile write; masked lanes drain into trash slot n
    dest = jnp.where(lf & (ranks < cnt), out_off + ranks, n)
    dstk_ref[dest] = kf
    for sv_ref, dv_ref in zip(srcv_refs, dstv_refs):
        vals = jnp.stack([sv_ref[pl.ds(starts[r], tpb)] for r in range(kway)])
        dv_ref[dest] = vals.reshape(-1)


@functools.partial(jax.jit, static_argnames=("kway", "tpb", "n", "interpret",
                                             "rank"))
def kway_merge_round(src_keys, src_vals, alt_keys, alt_vals, out_off, out_cnt,
                     win_start, win_take, *, kway: int, tpb: int, n: int,
                     interpret: bool = True, rank: str = "searchsorted"):
    """One k-way merge round over all groups in ONE Pallas launch.

    ``src_keys``/``src_vals`` hold the sorted runs back to back in a
    ``pad_length``-sized buffer (``src_vals`` is a tuple of value slabs);
    ``alt_*`` are the donated ping-pong targets.  The descriptor tables come
    from :func:`merge_path_partition` (device-resident rounds) or
    :func:`spill_group_plan` (host-spilled slab strips — there ``n`` is the
    slab capacity and the buffers are slab-sized).  Returns ``(new_keys,
    new_vals)`` with every group's runs merged in place of their span —
    exactly one ``pallas_call`` in the trace, the per-round / per-slab-sweep
    census invariant.  ``rank`` picks the tile-local merge: per-run-pair
    ``searchsorted`` co-ranks (default) or the legacy ``counting`` all-pairs
    rank; both produce byte-identical output (see :func:`_tile_rank`).
    """
    if rank not in ("searchsorted", "counting"):
        raise ValueError(f"unknown tile rank mode {rank!r}")
    g_max = out_off.shape[0]
    num_vals = len(src_vals)

    whole = lambda x: pl.BlockSpec(x.shape, lambda i, *_: (0,) * x.ndim)
    in_specs = ([whole(src_keys)] + [whole(v) for v in src_vals] +
                [whole(alt_keys)] + [whole(v) for v in alt_vals])
    out_specs = [whole(src_keys)] + [whole(v) for v in src_vals]
    out_shape = ([jax.ShapeDtypeStruct(src_keys.shape, src_keys.dtype)] +
                 [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in src_vals])
    # operand index space includes the 4 scalar-prefetch tables; the
    # alternate buffers donate their memory to the outputs
    alt0 = 4 + 1 + num_vals
    aliases = {alt0 + i: i for i in range(1 + num_vals)}

    out = pl.pallas_call(
        functools.partial(_kway_merge_kernel, kway=kway, tpb=tpb, n=n,
                          num_vals=num_vals, rank=rank),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(g_max,),
            in_specs=in_specs,
            out_specs=out_specs,
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(out_off, out_cnt, win_start, win_take,
      src_keys, *src_vals, alt_keys, *alt_vals)

    return out[0], tuple(out[1:1 + num_vals])
