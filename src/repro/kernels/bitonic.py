"""Pallas TPU kernel: VMEM-resident bitonic local sort (paper §4.1's local sort).

A bucket that fits on-chip is sorted with exactly one HBM read and one HBM
write no matter how many digit positions remain — the paper's biggest lever
for favourable distributions (4x on uniform keys).  The GPU version uses CUB's
BlockRadixSort in shared memory; the TPU-native engine is a bitonic sorting
network: branch-free, fully lane-parallel compare-exchange stages on the VPU,
over power-of-two rows staged in VMEM.

The host side realises the paper's *local sort configurations* optimisation
(§4.2): buckets are binned by size class and each class launches this kernel
with its own row width L, so tiny buckets don't pay ∂̂-sized padding.

Key-value pairs ride along through the same swap masks (§4.6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_stages(keys, vals):
    """Full bitonic network on (1, L) rows; vals may be None."""
    l = keys.shape[-1]
    assert (l & (l - 1)) == 0, "bitonic needs power-of-two rows"
    n_lev = l.bit_length() - 1
    idx = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1)
    for size_log in range(1, n_lev + 1):
        size = 1 << size_log
        for stride_log in range(size_log - 1, -1, -1):
            stride = 1 << stride_log
            partner = idx ^ stride                       # compare-exchange pairs
            pk = _swap_lanes(keys, stride)
            ascending = (idx & size) == 0
            is_lower = partner > idx
            take_min = ascending == is_lower
            kmin = jnp.minimum(keys, pk)
            kmax = jnp.maximum(keys, pk)
            new_keys = jnp.where(take_min, kmin, kmax)
            if vals is not None:
                pv = _swap_lanes(vals, stride)
                swapped = new_keys != keys
                # tie-safe value selection: move value iff the key moved
                vals = jnp.where(swapped, pv, vals)
            keys = new_keys
    return keys, vals


def _swap_lanes(x, stride):
    """x[..., i ^ stride] via reshape+flip (lane-aligned, no gather)."""
    b, l = x.shape
    y = x.reshape(b, l // (2 * stride), 2, stride)
    y = jnp.flip(y, axis=2)
    return y.reshape(b, l)


def _bitonic_stages_stable(keys, idx):
    """Bitonic network on (key, idx) with lexicographic compares.

    ``idx`` doubles as tie-break and payload: distinct per-lane indices make
    every compare strict, so the sort is deterministic for duplicate keys and
    an all-ones pad key cannot mix with a real all-ones key (pads carry the
    largest indices).  Returns keys sorted by (key, idx) plus the matching
    index permutation — the driver gathers values through it (§4.6).
    """
    l = keys.shape[-1]
    assert (l & (l - 1)) == 0, "bitonic needs power-of-two rows"
    n_lev = l.bit_length() - 1
    pos = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1)
    for size_log in range(1, n_lev + 1):
        size = 1 << size_log
        for stride_log in range(size_log - 1, -1, -1):
            stride = 1 << stride_log
            partner = pos ^ stride
            pk = _swap_lanes(keys, stride)
            pi = _swap_lanes(idx, stride)
            ascending = (pos & size) == 0
            is_lower = partner > pos
            take_min = ascending == is_lower
            mine_is_min = (keys < pk) | ((keys == pk) & (idx < pi))
            keep = take_min == mine_is_min
            keys = jnp.where(keep, keys, pk)
            idx = jnp.where(keep, idx, pi)
    return keys, idx


def _bitonic_kernel(keys_ref, out_ref):
    out_ref[...] = _bitonic_stages(keys_ref[...], None)[0]


def _bitonic_stable_kernel(keys_ref, idx_ref, out_k_ref, out_i_ref):
    k, i = _bitonic_stages_stable(keys_ref[...], idx_ref[...])
    out_k_ref[...] = k
    out_i_ref[...] = i


def _bitonic_kv_kernel(keys_ref, vals_ref, out_k_ref, out_v_ref):
    k, v = _bitonic_stages(keys_ref[...], vals_ref[...])
    out_k_ref[...] = k
    out_v_ref[...] = v


# rows per grid step: the network is batch-agnostic, so each VMEM block holds
# as many rows as fit a ~4 MiB element budget — small-L launches stop paying
# one grid step per tiny row (dominant for the local sort's padded tables)
_ROW_BATCH_ELEMS = 1 << 20


def _row_batch(s: int, l: int) -> int:
    return max(1, min(s, _ROW_BATCH_ELEMS // max(l, 1)))


def _rows_call(kernel, arrs, out_dtypes, interpret: bool):
    """Launch a row-batched bitonic kernel over (S, L) operand rows."""
    s, l = arrs[0].shape
    rb = _row_batch(s, l)
    pad = (-s) % rb
    if pad:
        arrs = [jnp.concatenate([a, a[-1:].repeat(pad, axis=0)]) for a in arrs]
    sp = s + pad
    spec = pl.BlockSpec((rb, l), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(sp // rb,),
        in_specs=[spec] * len(arrs),
        out_specs=[spec] * len(out_dtypes) if len(out_dtypes) > 1 else spec,
        out_shape=([jax.ShapeDtypeStruct((sp, l), dt) for dt in out_dtypes]
                   if len(out_dtypes) > 1
                   else jax.ShapeDtypeStruct((sp, l), out_dtypes[0])),
        interpret=interpret,
    )(*arrs)
    outs = out if isinstance(out, (tuple, list)) else (out,)
    outs = tuple(o[:s] for o in outs)
    return outs if len(outs) > 1 else outs[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_rows(keys: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Sort each row of (S, L) ascending; L must be a power of two."""
    return _rows_call(_bitonic_kernel, [keys], [keys.dtype], interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_rows_stable(keys: jnp.ndarray, idx: jnp.ndarray,
                             interpret: bool = True):
    """Sort (S, L) rows by (key, idx) lexicographically; L a power of two.

    ``idx`` must be distinct within each row (e.g. global positions): the sort
    is then stable in the original order and safe against sentinel-padding
    collisions — the segmented local-sort path of the hybrid sort's kernel
    engine relies on both properties.
    """
    return _rows_call(_bitonic_stable_kernel, [keys, idx],
                      [keys.dtype, idx.dtype], interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_rows_kv(keys: jnp.ndarray, vals: jnp.ndarray,
                         interpret: bool = True):
    """Sort (S, L) rows by key, carrying values; L must be a power of two.

    NOTE: with duplicate keys the value attribution is resolved by move-mask,
    which matches the paper's non-stable pair semantics.
    """
    return _rows_call(_bitonic_kv_kernel, [keys, vals],
                      [keys.dtype, vals.dtype], interpret)
