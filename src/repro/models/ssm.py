"""Mamba2 SSD (state-space duality) layer — chunked training + O(1) decode.

Follows the SSD block decomposition of arXiv:2405.21060: within-chunk terms
are attention-like masked contractions, cross-chunk terms propagate a
(heads, head_dim, state) recurrence.  Heads are sharded over the model axis
(d_inner / 16 per chip), which also bounds the (b, h, c, q, q) decay-mask
intermediate per chip.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

CONV_K = 4  # short depthwise causal conv window (mamba standard)


class SSMCache(NamedTuple):
    state: jnp.ndarray       # (B, H, P, N)
    conv: jnp.ndarray        # (B, CONV_K - 1, conv_dim) last inputs


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_ssm(key, cfg, dtype):
    ks = jax.random.split(key, 6)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj_out = 2 * di + 2 * n + h                 # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], d, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, conv_dim(cfg)), jnp.float32)
                   * 0.1).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),            # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _split_proj(proj, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, w):
    """Depthwise causal conv over time. xbc: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out)


def _segsum(x):
    """(..., Q) -> (..., Q, Q) stable segment sums: out[i, j] = sum_{j<t<=i} x_t."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(q)[:, None]
    jj = jnp.arange(q)[None, :]
    return jnp.where(jj <= ii, seg, -jnp.inf)


def ssm_forward(params, x, cfg, return_cache: bool = False):
    """Chunked SSD scan. x: (B, S, d) -> (B, S, d) (+ SSMCache for prefill).

    Sequences not divisible by the chunk length are zero-padded at the tail;
    causality keeps the padded positions from influencing real outputs.
    """
    s_orig = x.shape[1]
    q = min(cfg.ssm_chunk, s_orig)
    pad = (-s_orig) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    b, s, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    nc = s // q

    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(proj, cfg)
    xbc_raw = xbc                      # pre-conv inputs feed the decode cache
    xbc = _causal_conv(xbc, params["conv_w"])
    xin = xbc[..., :di].reshape(b, s, h, p)
    bmat = xbc[..., di:di + n]                          # (B, S, N) single group
    cmat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["A_log"])                       # (H,)
    da = dt * a                                         # (B,S,H)

    # chunk views
    xin_c = xin.reshape(b, nc, q, h, p)
    b_c = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    c_c = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    dt_c = dt.reshape(b, nc, q, h)
    da_c = da.reshape(b, nc, q, h)
    cum = jnp.cumsum(da_c, axis=2)                      # (B,NC,Q,H)

    # ---- intra-chunk (attention-like) term ----
    lmask = jnp.exp(_segsum(da_c.transpose(0, 1, 3, 2)))    # (B,NC,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", c_c, b_c)        # (B,NC,Q,Q)
    y_intra = jnp.einsum("bcij,bchij,bcjh,bcjhp->bcihp",
                         scores, lmask, dt_c,
                         xin_c.astype(jnp.float32))

    # ---- chunk states + inter-chunk recurrence ----
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,NC,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                        b_c, dt_c * decay_states, xin_c.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,NC,H)

    def scan_fn(prev, inp):
        st, dec = inp
        new = prev * dec[:, :, None, None] + st
        return new, prev

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init, (states.transpose(1, 0, 2, 3, 4),
                        chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,NC,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         c_c, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + params["D"][None, None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm_w"].astype(x.dtype), cfg.rms_eps)
    out = y @ params["out_proj"]
    out = out[:, :s_orig] if pad else out
    if not return_cache:
        return out
    # exact state handoff needs no tail padding (pad positions would apply
    # spurious decay); prefill shapes are chunk-aligned by construction
    assert pad == 0 and s_orig >= CONV_K - 1, "prefill must be chunk-aligned"
    conv_tail = xbc_raw[:, s_orig - (CONV_K - 1): s_orig, :]
    cache = SSMCache(state=final_state, conv=conv_tail)
    return out, cache


def ssm_decode_step(params, x, cache: SSMCache, cfg):
    """One-token step. x: (B, 1, d); O(1) state update (no KV growth)."""
    b, _, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x[:, 0] @ params["in_proj"]
    z, xbc, dt = _split_proj(proj, cfg)

    conv_in = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B,K,C)
    w = params["conv_w"]
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, w))
    new_conv = conv_in[:, 1:, :]

    xin = xbc[..., :di].reshape(b, h, p)
    bvec = xbc[..., di:di + n].astype(jnp.float32)
    cvec = xbc[..., di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt * a)                                    # (B,H)

    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xin.astype(jnp.float32), bvec)
    state = cache.state * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, cvec)
    y = y + params["D"][None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(b, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm_w"].astype(x.dtype), cfg.rms_eps)
    out = (y @ params["out_proj"])[:, None, :]
    return out, SSMCache(state=state, conv=new_conv)


def init_ssm_cache(cfg, batch: int, dtype):
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return SSMCache(state=jnp.zeros((batch, h, p, n), jnp.float32),
                    conv=jnp.zeros((batch, CONV_K - 1, conv_dim(cfg)), dtype))
