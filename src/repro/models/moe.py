"""Mixture-of-Experts layer with *sort-based* token dispatch.

The integration point of the paper: routing top-k tokens to E experts is a
single hybrid-radix counting pass on the expert id (E <= 2^d: qwen3's 128
experts are one d=7 digit, kimi-k2's 384 one d=9 digit).  The dispatch uses
``repro.core.segmented.capacity_dispatch`` — histogram, prefix-sum, scatter
(§4.1 steps 1–3) with the capacity row playing the paper's reserved memory
chunk (§4.4).  The underlying pass is ``core.plan.single_pass_partition``,
the same engine-selected primitive as length bucketing and the distributed
shard partition: one fused Pallas launch under interpret mode (or
``engine="kernel"`` explicitly), an XLA stable sort on compiled hardware
until the fused kernel's Mosaic lowering lands.

Dispatch is *grouped*: tokens are viewed as (G, T/G) with G = number of data
shards, so every group's counting pass stays shard-local (the distributed
analogue of the paper's per-block shared-memory partitioning) and only the
expert-major buffers cross the mesh to reach their (model-axis sharded)
experts.

A GShard-style dense one-hot dispatch is kept as the measured baseline
(``moe_dispatch="dense"``) — it is to the sort-based dispatch what CUB's LSD
sort is to the hybrid sort: same result, more memory traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.segmented import capacity_dispatch
from repro.models.layers import dense_init


def init_moe(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    scale = 0.02
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),   # fp32 routing
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale).astype(dtype),
    }


def _route(x_flat, router, top_k: int):
    from repro.models.layers import constrain, dp_axes
    from jax.sharding import PartitionSpec as P
    logits = x_flat.astype(jnp.float32) @ router            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = constrain(probs, P(dp_axes(), None))            # token-sharded top_k
    weights, ids = jax.lax.top_k(probs, top_k)              # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary (Switch-style)
    e = router.shape[1]
    frac_tokens = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return weights, ids.astype(jnp.int32), aux


def _expert_ffn(buf, params):
    """buf: (E, C, d) expert-major tokens -> (E, C, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


def _sort_dispatch(xg, ids, wts, params, e: int, capacity: int):
    """Sort-based dispatch/combine over (G, Tg, ·) grouped tokens.

    The heavy buffers are built OUTSIDE any vmap so their mesh layout can be
    constrained explicitly: groups stay on their data shards, the expert axis
    lives on the model axis — the per-chip wire volume is the EP optimum
    (tokens/chip x top_k x d each way), not a replicated buffer.
    """
    from repro.models.layers import constrain, dp_axes
    from jax.sharding import PartitionSpec as P
    g, tg, k = ids.shape
    d = xg.shape[-1]
    dp = dp_axes()

    flat_ids = ids.reshape(g, tg * k)
    # engine=None -> the backend-resolved shared partition engine (core.plan)
    cd = jax.vmap(lambda i: capacity_dispatch(i, e, capacity, engine=None))(
        flat_ids)
    token_of = jnp.minimum(cd.gather_idx, tg * k - 1) // k        # (G, E, C)
    # indices take the EP layout FIRST: the gather then reads the (model-)
    # replicated activations locally on each expert shard — zero dispatch wire.
    # gathers/scatters are vmapped over G so the batch dim stays structural
    # (an explicit arange(G) index makes GSPMD replicate the whole buffer).
    token_of = constrain(token_of, P(dp, "model", None))
    xg = constrain(xg, P(dp, None, None))
    buf = jax.vmap(lambda xr, tr: xr[tr])(xg, token_of)           # (G,E,C,d)
    buf = jnp.where(cd.slot_valid[..., None], buf, 0).astype(xg.dtype)
    buf = constrain(buf, P(dp, "model", None, None))              # EP layout

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    out = constrain(out, P(dp, "model", None, None))

    # combine: weighted scatter-add back to token-major order — each expert
    # shard contributes partial sums, GSPMD reduces them (one all-reduce of
    # (Tg, d) per group: the same wire as a dense TP block, independent of E).
    # Every scatter operand is constrained so the partial scatters stay
    # data-sharded on G and model-sharded on E (never replicated).
    wts_flat = jnp.take_along_axis(
        wts.reshape(g, tg * k),
        jnp.minimum(cd.gather_idx, tg * k - 1).reshape(g, -1), axis=1
    ).reshape(g, e, capacity)
    contrib = out * jnp.where(cd.slot_valid, wts_flat, 0.0)[..., None].astype(out.dtype)
    contrib = constrain(contrib, P(dp, "model", None, None))
    zeros = constrain(jnp.zeros((g, tg, d), out.dtype), P(dp, None, None))
    comb = jax.vmap(lambda z, t, c: z.at[t].add(c))(zeros, token_of, contrib)
    return constrain(comb, P(dp, None, None))                     # (G, Tg, d)


def _group_dispatch_dense(xg, ids, wts, params, e: int, capacity: int):
    """GShard-style dense one-hot dispatch (the measured baseline)."""
    tg, k = ids.shape
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.int32)        # (Tg, k, E)
    pos = jnp.cumsum(onehot.reshape(tg * k, e), axis=0).reshape(tg, k, e) - onehot
    kept = (pos < capacity) & (onehot > 0)
    poh = jax.nn.one_hot(jnp.where(kept, pos, capacity), capacity,
                         dtype=xg.dtype)                    # (Tg, k, E, C)
    mask = poh * kept[..., None].astype(xg.dtype)
    buf = jnp.einsum("tkec,td->ecd", mask, xg)              # dense scatter
    out = _expert_ffn(buf, params)
    per_assign = jnp.einsum("tkec,ecd->tkd", mask, out)
    return jnp.sum(per_assign * wts[..., None].astype(out.dtype), axis=1)


def moe_layer(params, x, cfg, *, groups: int = 1):
    """x: (B, S, d) -> (B, S, d), aux loss scalar."""
    b, s, d = x.shape
    t = b * s
    g = groups if t % groups == 0 else 1
    x_flat = x.reshape(t, d)
    wts, ids, aux = _route(x_flat, params["router"], cfg.top_k)
    from repro.models.layers import constrain, dp_axes
    from jax.sharding import PartitionSpec as P
    dp = dp_axes()
    wts = constrain(wts, P(dp, None))      # keep routing tables token-sharded
    ids = constrain(ids, P(dp, None))

    tg = t // g
    capacity = max(4, int(cfg.capacity_factor * tg * cfg.top_k / cfg.num_experts))
    capacity = min(capacity, tg * cfg.top_k)
    if cfg.moe_dispatch == "sort":
        out = _sort_dispatch(x_flat.reshape(g, tg, d),
                             ids.reshape(g, tg, cfg.top_k),
                             wts.reshape(g, tg, cfg.top_k),
                             params, cfg.num_experts, capacity)
    else:
        out = jax.vmap(_group_dispatch_dense, in_axes=(0, 0, 0, None, None, None))(
            x_flat.reshape(g, tg, d), ids.reshape(g, tg, cfg.top_k),
            wts.reshape(g, tg, cfg.top_k), params, cfg.num_experts, capacity)
    return out.reshape(b, s, d), aux


# --- contract declaration (verified by repro.analysis; see analysis/contracts)
# The sort-path MoE dispatch is one capacity_dispatch per token group: ONE
# counting pass (prologue histogram + fused launch) with the iota permutation
# riding as the single value leaf — the same contract shape as
# plan.single_pass_partition, declared here because the dispatch is the
# consumer whose traffic budget depends on it.
ANALYSIS_CONTRACT = {
    "entry": "repro.core.segmented.capacity_dispatch",
    "census": {
        "launch_total": "2",
        "while_body_launches": "[]",
        "fused_grid": "ceil_div(g_max, B)",
    },
    "sort_free": True,
    "donation": {"_fused_pass_kernel": "1 + vals"},
    "transfer": {
        "sweep_kernels": ["_hist_kernel", "_fused_pass_kernel"],
        "bytes": "(2 * passes + 1) * n_pad * kb + 2 * passes * n_pad * vb",
    },
}
