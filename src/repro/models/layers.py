"""Shared transformer building blocks (RMSNorm, RoPE, GQA attention, SwiGLU).

Functional style: ``init_*`` builds parameter pytrees (plain dicts), ``apply``
functions are pure.  Sharding is applied by name-based rules at the launcher
level (launch/sharding.py), so layers stay mesh-agnostic.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float = 0.02):
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` across jax versions (shim).

    Newer jax exposes the trace-time mesh directly; older releases (like the
    ``jax.shard_map``/``check_vma`` split handled in
    ``core.distributed._shard_map``) only know the physical mesh bound by the
    ``with mesh:`` context, reachable through ``thread_resources``.  Returns
    ``None`` when no mesh is bound either way, so the layer helpers below
    degrade to their off-mesh no-ops on every version.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        am = fn()
        return am if am and am.axis_names else None
    try:
        from jax._src.mesh import thread_resources
    except ImportError:                     # pragma: no cover - very old jax
        return None
    mesh = thread_resources.env.physical_mesh
    return mesh if mesh.axis_names else None


def dp_axes():
    """Batch-carrying mesh axes visible at trace time (() off-mesh)."""
    am = abstract_mesh()
    names = tuple(am.axis_names or ()) if am else ()
    return tuple(a for a in ("pod", "data") if a in names)


def constrain(x, spec):
    """with_sharding_constraint that no-ops off-mesh (smoke tests, 1 device).

    Layers stay mesh-agnostic: constraints bind only when the launcher traces
    under ``jax.set_mesh`` (axis names resolved from the abstract mesh).
    """
    am = abstract_mesh()
    names = set(am.axis_names or ()) if am else set()
    used = {a for part in spec if part is not None
            for a in (part if isinstance(part, tuple) else (part,))}
    if not used or not used.issubset(names):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def rms_norm(x, weight, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------- GQA attention ----------------------------------

def init_attention(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads_padded, cfg.n_kv_padded   # TP head padding (see config)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }


def _pad_head_mask(cfg):
    """Validity mask over padded Q heads: pad heads contribute exactly zero
    (and receive zero gradients), so the padded model computes the unpadded
    architecture while every head tensor shards over the model axis."""
    h, kv = cfg.n_heads_padded, cfg.n_kv_padded
    n_rep = h // kv
    rep_real = cfg.n_heads // cfg.n_kv_heads
    hidx = jnp.arange(h)
    return ((hidx // n_rep < cfg.n_kv_heads)
            & (hidx % n_rep < rep_real))


def _gqa_scores(q, k, n_rep: int):
    """q: (B,S,H,hd), k: (B,T,KV,hd) -> (B,H,S,T).

    KV heads are broadcast to H (jnp.repeat) instead of folding H into
    (KV, rep): a reshape of the model-sharded H axis would break the head
    sharding and make GSPMD replicate the O(S^2) score tensor per chip.
    """
    k = jnp.repeat(k, n_rep, axis=2) if n_rep > 1 else k
    return jnp.einsum("bshd,bthd->bhst", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_values(probs, v, n_rep: int):
    """probs: (B,H,S,T) in v.dtype, v: (B,T,KV,hd) -> (B,S,H,hd) f32-accum."""
    v = jnp.repeat(v, n_rep, axis=2) if n_rep > 1 else v
    return jnp.einsum("bhst,bthd->bshd", probs, v,
                      preferred_element_type=jnp.float32)


def attention(params, x, cfg, *, positions=None, kv_cache=None,
              cache_len=None, window=None, dtype=None):
    """GQA attention in three modes:

      train/prefill: kv_cache=None — full causal self-attention (optionally
        sliding-window limited for hybrid archs);
      decode: kv_cache=(k,v) with static length T — x is (B, 1, d), cache_len
        gives the number of valid cache entries; returns updated cache.
    """
    b, s, d = x.shape
    hd, h, kvh = cfg.head_dim, cfg.n_heads_padded, cfg.n_kv_padded
    n_rep = h // kvh
    padded = bool(cfg.head_pad_to or cfg.kv_pad_to)
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kvh, hd)
    v = (x @ params["wv"]).reshape(b, s, kvh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        if getattr(cfg, "attention_impl", "naive") == "flash":
            k_rep = jnp.repeat(k, n_rep, axis=2) if n_rep > 1 else k
            v_rep = jnp.repeat(v, n_rep, axis=2) if n_rep > 1 else v
            out = flash_attention(q, k_rep, v_rep, positions, window,
                                  min(cfg.flash_block, s))
        else:
            scores = _gqa_scores(q, k, n_rep) / jnp.sqrt(hd).astype(jnp.float32)
            ii = positions[:, None, :, None]              # query pos
            jj = positions[:, None, None, :]              # key pos
            mask = jj <= ii
            if window is not None:                 # traced per-layer window; 0 = full
                mask &= (window == 0) | (jj > ii - window)
            scores = jnp.where(mask, scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
            out = _gqa_values(probs, v, n_rep)
        new_cache = (k, v)          # callers collecting a prefill cache use this
    else:
        ck, cv = kv_cache                                  # (B, T, KV, hd)
        t = ck.shape[1]
        idx = cache_len.astype(jnp.int32)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, idx, 0, 0))
        scores = _gqa_scores(q, ck, n_rep) / jnp.sqrt(hd).astype(jnp.float32)
        jj = jnp.arange(t, dtype=jnp.int32)[None, None, None, :]
        valid = jj <= cache_len
        if window is not None:                     # traced per-layer window
            valid &= (window == 0) | (jj > cache_len - window)
        scores = jnp.where(valid, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
        out = _gqa_values(probs, cv, n_rep)
        new_cache = (ck, cv)

    if padded:
        out = out * _pad_head_mask(cfg)[None, None, :, None].astype(out.dtype)
    out = out.reshape(b, s, h * hd).astype(x.dtype) @ params["wo"]
    return out, new_cache


# --------------------------- flash attention --------------------------------

def flash_attention(q, k, v, positions, window, block: int):
    """Blockwise online-softmax attention (Rabe & Staats / FlashAttention).

    q: (B,S,H,hd); k,v already KV-head-broadcast to (B,T,H,hd).
    Never materialises the (B,H,S,T) score tensor: the KV axis is streamed in
    ``block``-sized tiles with running max/denominator — the structural fix
    for the memory-bound roofline term of every train/prefill cell.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    pad = (-t) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = k.shape[1] // block
    kb = k.reshape(b, nblk, block, h, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block, h, hd).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / float(np_sqrt(hd))
    ii = positions[:, None, :, None]                       # (B,1,S,1)

    def step(carry, inp):
        m, l, acc = carry                                  # (B,H,S),(B,H,S),(B,S,H,hd)
        kblk, vblk, t0 = inp
        sblk = jnp.einsum("bshd,bthd->bhst", q, kblk,
                          preferred_element_type=jnp.float32) * scale
        jj = (t0 + jnp.arange(block, dtype=jnp.int32))[None, None, None, :]
        mask = (jj <= ii) & (jj < t)
        if window is not None:
            mask &= (window == 0) | (jj > ii - window)
        sblk = jnp.where(mask, sblk, -1e30)
        m_new = jnp.maximum(m, sblk.max(axis=-1))
        p = jnp.exp(sblk - m_new[..., None])               # (B,H,S,blk)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhst,bthd->bshd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, s), -jnp.inf, jnp.float32),
            jnp.zeros((b, h, s), jnp.float32),
            jnp.zeros((b, s, h, hd), jnp.float32))
    t0s = jnp.arange(nblk, dtype=jnp.int32) * block
    (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, t0s))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out


def np_sqrt(x):
    import math
    return math.sqrt(x)


# --------------------------- SwiGLU MLP -------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {"w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype)}


def mlp(params, x):
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]
