"""Model zoo: config-driven architectures for all assigned families."""
from repro.models.transformer import (init_params, forward, loss_fn, prefill,
                                      decode_step, init_cache, DecodeCache)

__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step",
           "init_cache", "DecodeCache"]
