"""Config-driven model assembly for all assigned architecture families.

Families:
  dense  — pre-norm GQA + SwiGLU (internlm2, deepseek, phi4; musicgen over
           EnCodec-token stub; internvl2 with patch-embedding stub frontend)
  moe    — GQA + sort-dispatched MoE FFN (qwen3-moe, kimi-k2)
  ssm    — Mamba2 SSD blocks, attention-free
  hybrid — Hymba: parallel attention+SSM heads per block, SWA except listed
           global layers, + SwiGLU FFN

Layers are scan-stacked (params carry a leading L dim) so the HLO stays O(1)
in depth — essential for the 95-layer deepseek-67b dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM


def _seq_shard(x, cfg):
    """Sequence-parallel residual stream (Korthikanti et al.): the saved
    per-layer carry is sharded over the model axis on the sequence dim, so
    remat checkpoints cost 1/|model| of the replicated layout.  GSPMD inserts
    the all-gather where a block needs the full sequence (attention/SSM)."""
    if not cfg.seq_shard_activations or x.shape[1] % 2:
        return x
    return L.constrain(x, P(L.dp_axes(), "model", None))


# ----------------------------- init -----------------------------------------

def _init_block(key, cfg, dtype):
    p: Dict[str, Any] = {}
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        p["attn_norm"] = jnp.ones((d,), jnp.float32)
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
        p["ffn_norm"] = jnp.ones((d,), jnp.float32)
        if cfg.is_moe:
            p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], d, cfg.d_ff, dtype)
    elif cfg.family == "ssm":
        p["norm"] = jnp.ones((d,), jnp.float32)
        p["ssm"] = SSM.init_ssm(ks[0], cfg, dtype)
    elif cfg.family == "hybrid":
        p["in_norm"] = jnp.ones((d,), jnp.float32)
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
        p["ssm"] = SSM.init_ssm(ks[1], cfg, dtype)
        p["b_attn"] = jnp.float32(0.5)
        p["b_ssm"] = jnp.float32(0.5)
        p["ffn_norm"] = jnp.ones((d,), jnp.float32)
        p["mlp"] = L.init_mlp(ks[2], d, cfg.d_ff, dtype)
    else:
        raise ValueError(cfg.family)
    return p


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    v, d = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": (jax.random.normal(ks[0], (v, d), jnp.float32) * 0.02).astype(dtype),
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": jax.vmap(lambda k: _init_block(k, cfg, dtype))(
            jax.random.split(ks[1], cfg.n_layers)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[2], d, v, dtype)
    return params


def _windows(cfg) -> jnp.ndarray:
    """Per-layer attention window (0 = full attention)."""
    w = jnp.full((cfg.n_layers,), cfg.attn_window, jnp.int32)
    if cfg.global_attn_layers:
        w = w.at[jnp.asarray(cfg.global_attn_layers)].set(0)
    return w


# ----------------------------- forward --------------------------------------

def _block_fwd(bp, x, cfg, window, positions):
    aux = jnp.float32(0.0)
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        h, _ = L.attention(bp["attn"], L.rms_norm(x, bp["attn_norm"], cfg.rms_eps),
                           cfg, positions=positions, window=window)
        x = x + checkpoint_name(h, "attn_out")
        y = L.rms_norm(x, bp["ffn_norm"], cfg.rms_eps)
        if cfg.is_moe:
            m, aux = MOE.moe_layer(bp["moe"], y, cfg, groups=cfg_groups(cfg))
            x = x + checkpoint_name(m, "ffn_out")
        else:
            x = x + checkpoint_name(L.mlp(bp["mlp"], y), "ffn_out")
    elif cfg.family == "ssm":
        h = SSM.ssm_forward(bp["ssm"], L.rms_norm(x, bp["norm"], cfg.rms_eps), cfg)
        x = x + checkpoint_name(h, "ffn_out")
    elif cfg.family == "hybrid":
        y = L.rms_norm(x, bp["in_norm"], cfg.rms_eps)
        a, _ = L.attention(bp["attn"], y, cfg, positions=positions, window=window)
        s = SSM.ssm_forward(bp["ssm"], y, cfg)
        x = x + checkpoint_name((bp["b_attn"] * a.astype(jnp.float32)
                 + bp["b_ssm"] * s.astype(jnp.float32)).astype(x.dtype), "attn_out")
        x = x + checkpoint_name(
            L.mlp(bp["mlp"], L.rms_norm(x, bp["ffn_norm"], cfg.rms_eps)), "ffn_out")
    return _seq_shard(x, cfg), aux


def cfg_groups(cfg) -> int:
    return cfg.dispatch_groups


def _embed_inputs(params, cfg, batch):
    """batch: {"tokens": (B,S)} (+ "patches": (B,P,d) for vlm)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.frontend == "vision_patches":
        patches = batch["patches"].astype(x.dtype)       # precomputed stub
        x = jnp.concatenate([patches, x], axis=1)
    return x


def forward(params, cfg, batch, *, remat: bool = False):
    """Full-sequence forward -> logits (B, S_total, V)."""
    x = _embed_inputs(params, cfg, batch)
    b, s, d = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    windows = _windows(cfg)

    def body(carry, xs):
        bp, w = xs
        y, aux = _block_fwd(bp, carry[0], cfg, w, positions)
        return (y, carry[1] + aux), None

    if remat and cfg.remat_policy == "save_block_io":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "ffn_out"))
    elif remat:
        body_fn = jax.checkpoint(body)
    else:
        body_fn = body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               (params["layers"], windows),
                               unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, aux


def loss_fn(params, cfg, batch, *, remat: bool = True):
    """Next-token cross-entropy; for vlm the patch positions are excluded.

    The vocab axis of the logits is model-sharded; the CE uses only masked
    reductions over it (max / sum / one-hot contraction), never a gather —
    a gather would force GSPMD to all-gather the (B, S, V) logits.
    """
    logits, aux = forward(params, cfg, batch, remat=remat)
    tokens = batch["tokens"]
    n_prefix = logits.shape[1] - tokens.shape[1]           # vlm patch positions
    logits = logits[:, n_prefix:, :][:, :-1, :]
    targets = tokens[:, 1:]
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(iota == targets[..., None], lf, 0.0), axis=-1)
    ce = jnp.mean(logz - gold)
    return ce + 0.01 * aux / cfg.n_layers, {"ce": ce, "aux": aux}


# ----------------------------- prefill --------------------------------------

def _block_prefill(bp, x, cfg, window, positions):
    """Like _block_fwd but collects the per-layer decode cache."""
    kv = ssm_c = None
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        h, kv = L.attention(bp["attn"], L.rms_norm(x, bp["attn_norm"], cfg.rms_eps),
                            cfg, positions=positions, window=window)
        x = x + h
        y = L.rms_norm(x, bp["ffn_norm"], cfg.rms_eps)
        if cfg.is_moe:
            m, _ = MOE.moe_layer(bp["moe"], y, cfg, groups=cfg_groups(cfg))
            x = x + m
        else:
            x = x + L.mlp(bp["mlp"], y)
    elif cfg.family == "ssm":
        h, ssm_c = SSM.ssm_forward(bp["ssm"], L.rms_norm(x, bp["norm"], cfg.rms_eps),
                                   cfg, return_cache=True)
        x = x + h
    elif cfg.family == "hybrid":
        y = L.rms_norm(x, bp["in_norm"], cfg.rms_eps)
        a, kv = L.attention(bp["attn"], y, cfg, positions=positions, window=window)
        s, ssm_c = SSM.ssm_forward(bp["ssm"], y, cfg, return_cache=True)
        x = x + (bp["b_attn"] * a.astype(jnp.float32)
                 + bp["b_ssm"] * s.astype(jnp.float32)).astype(x.dtype)
        x = x + L.mlp(bp["mlp"], L.rms_norm(x, bp["ffn_norm"], cfg.rms_eps))
    return _seq_shard(x, cfg), kv, ssm_c


def prefill(params, cfg, batch, *, max_len: int = 0, remat: bool = True):
    """Process the prompt; return (last-token logits (B,1,V), DecodeCache).

    ``max_len`` reserves cache slots beyond the prompt (0 = exactly prompt).
    """
    x = _embed_inputs(params, cfg, batch)
    b, s, d = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    windows = _windows(cfg)
    max_len = max(max_len, s)

    def body(carry, xs):
        bp, w = xs
        y, kv, ssm_c = _block_prefill(bp, carry, cfg, w, positions)
        return y, (kv, ssm_c)

    body_fn = jax.checkpoint(body) if remat else body
    x, (kvs, ssm_cs) = jax.lax.scan(body_fn, x, (params["layers"], windows),
                                    unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = L.rms_norm(x[:, -1:, :], params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head

    kv_k = kv_v = ssm_state = ssm_conv = None
    if cfg.has_attention:
        pad = max_len - s
        kv_k = jnp.pad(kvs[0], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        kv_v = jnp.pad(kvs[1], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if cfg.has_ssm:
        ssm_state, ssm_conv = ssm_cs.state, ssm_cs.conv
    cache = DecodeCache(kv_k, kv_v, ssm_state, ssm_conv, jnp.int32(s))
    return logits, cache


# ----------------------------- decode ---------------------------------------

class DecodeCache(NamedTuple):
    kv_k: Optional[jnp.ndarray]       # (L, B, T, KV, hd)
    kv_v: Optional[jnp.ndarray]
    ssm_state: Optional[jnp.ndarray]  # (L, B, H, P, N)
    ssm_conv: Optional[jnp.ndarray]   # (L, B, K-1, conv_dim)
    length: jnp.ndarray               # () int32 — tokens already in cache


def init_cache(cfg, batch: int, max_len: int, dtype=None) -> DecodeCache:
    dtype = dtype or jnp.dtype(cfg.dtype)
    l = cfg.n_layers
    kv_k = kv_v = ssm_state = ssm_conv = None
    if cfg.has_attention:
        shp = (l, batch, max_len, cfg.n_kv_padded, cfg.head_dim)
        kv_k = jnp.zeros(shp, dtype)
        kv_v = jnp.zeros(shp, dtype)
    if cfg.has_ssm:
        ssm_state = jnp.zeros((l, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                               cfg.ssm_state), jnp.float32)
        ssm_conv = jnp.zeros((l, batch, SSM.CONV_K - 1, SSM.conv_dim(cfg)), dtype)
    return DecodeCache(kv_k, kv_v, ssm_state, ssm_conv, jnp.int32(0))


def _block_decode(bp, x, cfg, window, cache_sl, length):
    """One layer, one token. cache_sl: per-layer cache slices."""
    kv_k, kv_v, s_state, s_conv = cache_sl
    positions = jnp.full((x.shape[0], 1), length, jnp.int32)
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        h, (nk, nv) = L.attention(bp["attn"],
                                  L.rms_norm(x, bp["attn_norm"], cfg.rms_eps),
                                  cfg, positions=positions,
                                  kv_cache=(kv_k, kv_v), cache_len=length,
                                  window=window)
        x = x + h
        y = L.rms_norm(x, bp["ffn_norm"], cfg.rms_eps)
        if cfg.is_moe:
            m, _ = MOE.moe_layer(bp["moe"], y, cfg, groups=cfg_groups(cfg))
            x = x + m
        else:
            x = x + L.mlp(bp["mlp"], y)
        return x, (nk, nv, s_state, s_conv)
    if cfg.family == "ssm":
        h, nc = SSM.ssm_decode_step(bp["ssm"],
                                    L.rms_norm(x, bp["norm"], cfg.rms_eps),
                                    SSM.SSMCache(s_state, s_conv), cfg)
        return x + h, (kv_k, kv_v, nc.state, nc.conv)
    if cfg.family == "hybrid":
        y = L.rms_norm(x, bp["in_norm"], cfg.rms_eps)
        a, (nk, nv) = L.attention(bp["attn"], y, cfg, positions=positions,
                                  kv_cache=(kv_k, kv_v), cache_len=length,
                                  window=window)
        s, nc = SSM.ssm_decode_step(bp["ssm"], y, SSM.SSMCache(s_state, s_conv), cfg)
        x = x + (bp["b_attn"] * a.astype(jnp.float32)
                 + bp["b_ssm"] * s.astype(jnp.float32)).astype(x.dtype)
        x = x + L.mlp(bp["mlp"], L.rms_norm(x, bp["ffn_norm"], cfg.rms_eps))
        return x, (nk, nv, nc.state, nc.conv)
    raise ValueError(cfg.family)


def decode_step(params, cfg, token, cache: DecodeCache):
    """token: (B, 1) int32 -> (logits (B, 1, V), updated cache)."""
    x = params["embed"][token]
    windows = _windows(cfg)
    dummy = jnp.zeros((cfg.n_layers, 0), jnp.int8)   # uniform scan xs stand-in

    def body(carry, xs):
        bp, w, ck, cv, ss, sc = xs
        y, new_sl = _block_decode(bp, carry, cfg, w,
                                  (ck, cv, ss, sc), cache.length)
        return y, new_sl

    xs = (params["layers"], windows,
          cache.kv_k if cache.kv_k is not None else dummy,
          cache.kv_v if cache.kv_v is not None else dummy,
          cache.ssm_state if cache.ssm_state is not None else dummy,
          cache.ssm_conv if cache.ssm_conv is not None else dummy)
    x, new_caches = jax.lax.scan(body, x, xs,
                                 unroll=cfg.n_layers if cfg.scan_unroll else 1)
    nk, nv, ns, nc = new_caches
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    new = DecodeCache(
        kv_k=nk if cache.kv_k is not None else None,
        kv_v=nv if cache.kv_v is not None else None,
        ssm_state=ns if cache.ssm_state is not None else None,
        ssm_conv=nc if cache.ssm_conv is not None else None,
        length=cache.length + 1)
    return logits, new
