"""HLO-text analysis: collective bytes-on-wire for the roofline's third term.

``cost_analysis()`` reports FLOPs and HBM bytes but not collective traffic,
so we parse the partitioned HLO and sum the bytes every collective moves
across ICI, weighted by the op's wire factor:

  all-gather          out * (P-1)/P     (each chip receives P-1 shards)
  reduce-scatter      in  * (P-1)/P
  all-reduce          2 * size * (P-1)/P  (ring = RS + AG)
  all-to-all          size * (P-1)/P
  collective-permute  size              (one hop)

Shapes in the SPMD module are per-device, so the sums are per-chip wire bytes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# async collectives split into ``-start``/``-done`` pairs: the start op's
# result is a tuple carrying operand + output + context buffers (summing it
# double-counts), the done op's result is the true output.  The suffix is
# captured so bytes can be read off done/plain lines and sites counted off
# start/plain lines — each pair exactly once either way.
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:                                   # [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def collective_bytes(hlo_text: str, num_devices: int) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind (+ 'total').

    Async pairs are counted once, at the ``-done`` op (its result is the
    true output shape; the ``-start`` result tuple also carries operand and
    context buffers).  ``replica_groups`` usually annotates only the start
    line, so the group size seen at a start is carried to its done.
    """
    out: Dict[str, float] = defaultdict(float)
    start_groups: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind, suffix = m.group(1), m.group(2), m.group(3) or ""
        if suffix == "-start":
            start_groups[kind] = _group_size(line, num_devices)
            continue
        size = _shape_bytes(shape_str)
        default_p = (start_groups.pop(kind, num_devices)
                     if suffix == "-done" else num_devices)
        p = max(_group_size(line, default_p), 1)
        frac = (p - 1) / p
        if kind == "all-reduce":
            wire = 2 * size * frac
        elif kind == "all-gather":
            wire = size * frac
        elif kind == "reduce-scatter":
            wire = size * p * frac          # shape is the scattered output
        elif kind == "all-to-all":
            wire = size * frac
        else:                                # collective-permute
            wire = size
        out[kind] += wire
        out["total"] += wire
    return dict(out)


# ----- generic op census (used to certify sort-free kernel engines) --------

# Lowered text is either StableHLO ("%3 = stablehlo.sort(...)" /
# '"stablehlo.sort"(...)') or HLO text ("%x = ... sort(...)").  Attribute
# noise like ``indices_are_sorted=`` or function names like ``@argsort`` must
# not count, hence the tight patterns.
_STABLEHLO_OP_RE = re.compile(r'"?stablehlo\.([\w.]+)"?\(')
_HLO_OP_RE = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][\w-]*)\(")


def op_counts(hlo_text: str) -> Dict[str, int]:
    """Histogram of op names appearing in lowered StableHLO/HLO text."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _STABLEHLO_OP_RE.search(line)
        if m:
            out[m.group(1)] += 1
            continue
        m = _HLO_OP_RE.search(line)
        if m:
            out[m.group(1)] += 1
    return dict(out)


def sort_op_count(hlo_text: str) -> int:
    """Number of (stable)HLO ``sort`` ops in lowered text.

    ``jnp.argsort``/``jnp.lexsort``/``jnp.sort`` all lower to this op, so a
    zero count certifies a computation is free of comparison sorts — the
    acceptance gate for the Pallas kernel engine of ``hybrid_sort``.
    """
    return op_counts(hlo_text).get("sort", 0)


def collective_counts(hlo_text: str) -> Dict[str, int]:
    """Collective sites by kind; an async ``-start``/``-done`` pair is one
    site (counted at the start, where the op is issued)."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m and (m.group(3) or "") != "-done":
            out[m.group(2)] += 1
    return dict(out)


# ----- Pallas launch census (fused-engine acceptance gate) ------------------

# On real hardware a pallas_call lowers to a custom call with one of these
# targets; in interpret mode (this CPU container) the launch only exists as
# the ``pallas_call`` primitive in the jaxpr, so both counters are provided.
_PALLAS_CUSTOM_CALL_RE = re.compile(
    r'custom[-_]call(?:_target)?\s*[=(]?\s*@?"?'
    r'(tpu_custom_call|mosaic|__gpu\$xla\.gpu\.triton|triton_kernel_call)')


def pallas_custom_call_count(hlo_text: str) -> int:
    """Number of Pallas-kernel custom calls in lowered StableHLO/HLO text."""
    return len(_PALLAS_CUSTOM_CALL_RE.findall(hlo_text))


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if hasattr(x, "eqns"):                  # open Jaxpr
                yield x
            elif hasattr(x, "jaxpr") and hasattr(x.jaxpr, "eqns"):
                yield x.jaxpr                       # ClosedJaxpr


def jaxpr_primitive_counts(jaxpr) -> Dict[str, int]:
    """Recursive histogram of primitive names in a (Closed)Jaxpr.

    Sub-jaxprs (jit/while/cond/scan bodies) are traversed; a while body is
    counted ONCE, which is exactly what makes this the per-pass launch
    census: the fused engine's counting-pass loop body must contain exactly
    one ``pallas_call`` no matter how many passes execute at runtime.
    """
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    out: Dict[str, int] = defaultdict(int)
    for eqn in jaxpr.eqns:
        out[eqn.primitive.name] += 1
        for sub in _sub_jaxprs(eqn):
            for name, c in jaxpr_primitive_counts(sub).items():
                out[name] += c
    return dict(out)


def pallas_launch_count(jaxpr) -> int:
    """Total ``pallas_call`` launch sites in a traced computation."""
    return jaxpr_primitive_counts(jaxpr).get("pallas_call", 0)


def launch_census(jaxpr) -> Dict[str, object]:
    """One-call launch census: total ``pallas_call`` sites + per-while-body
    counts.

    The two structural invariants of the sort pipelines read straight off
    this: the fused hybrid engine traces to ``{"total": 2 +
    len(core.hybrid.local_sort_classes(n, cfg)), "while_bodies": [1]}``
    (prologue + ONE launch per counting pass + one bitonic launch per
    local-sort size class), and every
    out-of-core merge *round* — a host-driven jit with no device loop —
    traces to ``{"total": 1, "while_bodies": []}``: one ``pallas_call`` per
    round, ``⌈log_K(runs)⌉`` rounds per sort (§5).  Any binary-search loop
    of the merge-path partition that traces as a while must stay launch-free
    (a zero entry in ``while_bodies``).
    """
    return {"total": pallas_launch_count(jaxpr),
            "while_bodies": while_body_pallas_launches(jaxpr)}


def pallas_grid_sizes(jaxpr):
    """Grid shapes of every ``pallas_call`` site, in trace order.

    The batched-step census: packing B block descriptors per grid step
    (``plan.pack_region_blocks``) must shrink the fused launch's grid from
    ``g_max`` to ``⌈g_max/B⌉`` *without* changing the launch count — the
    launch-site invariants above stay as they are, and this counter pins the
    grid side of the contract.  Each entry is a tuple (the pallas grid), one
    per launch site found (while/cond/jit bodies are traversed like
    ``jaxpr_primitive_counts``; a while body's site is counted once).
    """
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(tuple(eqn.params["grid_mapping"].grid))
        for sub in _sub_jaxprs(eqn):
            out.extend(pallas_grid_sizes(sub))
    return out


def while_body_pallas_launches(jaxpr):
    """Launch sites inside each while-loop body, outermost-first.

    For the fused hybrid engine this returns ``[1]``: one Pallas launch per
    counting pass (the loop body), with the prologue histogram and the local
    sort outside the loop.
    """
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            out.append(pallas_launch_count(eqn.params["body_jaxpr"]))
        else:
            for sub in _sub_jaxprs(eqn):
                out.extend(while_body_pallas_launches(sub))
    return out
