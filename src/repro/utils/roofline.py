"""Roofline math for the TPU v5e target.

Hardware constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI, 16 GiB HBM capacity.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
HBM_CAP = 16 * 1024**3       # bytes per chip (v5e)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    step: str
    mesh: str
    chips: int
    flops_per_chip: float          # from cost_analysis (per-device module)
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops_global: float      # 6*N*D (active params for MoE)
    mem_per_chip: float            # from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time: overlapped execution => max of the terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — remat/dispatch/padding waste detector."""
        total = self.flops_per_chip * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-implied MFU: useful FLOPs over peak during t_bound."""
        denom = self.t_bound * PEAK_FLOPS * self.chips
        return self.model_flops_global / denom if denom else 0.0

    @property
    def fits(self) -> bool:
        return self.mem_per_chip <= HBM_CAP

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "step": self.step,
            "mesh": self.mesh, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_global,
            "hlo_flops_per_chip": self.flops_per_chip,
            "useful_flops_frac": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
            "mem_per_chip_gib": self.mem_per_chip / 1024**3,
            "fits_16gib": self.fits,
        }


def model_flops(cfg, shape_cfg) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode D = one token per sequence."""
    n = cfg.active_param_count()
    if shape_cfg.kind == "train":
        return 6.0 * n * shape_cfg.tokens
    if shape_cfg.kind == "prefill":
        return 2.0 * n * shape_cfg.tokens          # forward only
    return 2.0 * n * shape_cfg.global_batch        # decode: 1 new token/seq
