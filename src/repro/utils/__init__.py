from repro.utils.hlo import collective_bytes, collective_counts
from repro.utils.roofline import Roofline, model_flops, PEAK_FLOPS, HBM_BW, ICI_BW

__all__ = ["collective_bytes", "collective_counts", "Roofline", "model_flops",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
