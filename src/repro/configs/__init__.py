"""Assigned architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shapes_for

ARCHS = [
    "qwen3_moe_30b_a3b", "kimi_k2_1t_a32b", "musicgen_medium",
    "internlm2_1_8b", "deepseek_67b", "phi4_mini_3_8b", "deepseek_7b",
    "hymba_1_5b", "mamba2_1_3b", "internvl2_26b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: tiny width/depth/vocab for CPU smoke tests."""
    cfg = get_config(arch)
    updates = dict(
        n_layers=2, d_model=64, vocab=256, vocab_pad_multiple=16,
        rope_theta=1e4, dtype="float32",
    )
    if cfg.has_attention:
        updates.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
                       head_dim=16)
    if cfg.is_moe:
        updates.update(num_experts=8, top_k=2, d_ff=32)
    elif cfg.d_ff:
        updates.update(d_ff=128)
    if cfg.has_ssm:
        updates.update(ssm_state=8, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        updates.update(attn_window=8, global_attn_layers=(0,))
    if cfg.frontend == "vision_patches":
        updates.update(num_patches=4)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **updates)


__all__ = ["ARCHS", "get_config", "get_smoke_config", "ModelConfig",
           "ShapeConfig", "SHAPES", "shapes_for"]
