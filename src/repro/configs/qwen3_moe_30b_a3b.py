"""Qwen3-30B-A3B: 128-expert top-8 MoE. [hf:Qwen/Qwen3-30B-A3B; hf]

The paper's technique is CORE here: expert dispatch is one d=7 counting pass.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936, num_experts=128, top_k=8,
    rope_theta=1e6, optimizer="adamw", fsdp_params=True, seq_shard_activations=True,
)
