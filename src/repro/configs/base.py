"""Architecture configuration schema for all assigned model families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free
    n_kv_heads: int
    d_ff: int                     # dense FFN width (expert width for moe)
    vocab: int

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_dispatch: str = "sort"    # "sort" (paper technique) | "dense" (baseline)
    dispatch_groups: int = 1      # launcher sets to the data-shard count
    capacity_factor: float = 1.25

    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # hybrid (hymba): sliding-window attention everywhere except these layers
    attn_window: int = 0          # 0 = full attention
    global_attn_layers: Tuple[int, ...] = ()

    # TP head padding (Megatron-style): pad Q (and optionally KV) head counts
    # up to a model-axis multiple so attention shards instead of replicating.
    # Pad heads are zero-initialised AND output-masked — the math is exactly
    # the unpadded architecture (tested).
    head_pad_to: int = 0          # 0 = off; else padded Q head count
    kv_pad_to: int = 0            # 0 = off; else padded KV head count

    # misc
    head_dim: int = 0             # 0 -> d_model // n_heads
    rope_theta: float = 1e4
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256
    dtype: str = "bfloat16"

    # modality frontends are stubs: input_specs() provides embeddings directly
    frontend: str = "none"        # none | audio_tokens | vision_patches
    num_patches: int = 0          # vlm: patch embeddings prepended per image

    # which input shapes apply (long_500k only for sub-quadratic archs)
    supports_long_context: bool = False

    # large-model memory knobs (per-arch defaults; launcher may override)
    optimizer: str = "adamw"      # adamw | adafactor | adamw8bit
    remat: bool = True
    fsdp_params: bool = False     # storage-shard expert/ffn params over data
    scan_unroll: bool = False     # unroll the layer scan (cost-analysis fits)
    seq_shard_activations: bool = False  # sequence-parallel residual stream
    attention_impl: str = "naive"  # naive (materialised S^2) | flash (blockwise)
    flash_block: int = 512         # KV block for the flash path
    remat_policy: str = "full"     # full | save_block_io (keep collective
                                   # outputs: no re-all-reduce in backward)

    def __post_init__(self):
        if self.n_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, self.vocab_pad_multiple)

    @property
    def n_heads_padded(self) -> int:
        return self.head_pad_to or self.n_heads

    @property
    def n_kv_padded(self) -> int:
        return self.kv_pad_to or self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def d_inner(self) -> int:     # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, l = self.d_model, self.n_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.has_attention:
            hq = self.n_heads * self.head_dim
            hkv = self.n_kv_heads * self.head_dim
            per_layer += d * hq * 2 + d * hkv * 2
        if self.has_ssm:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer += d * (2 * di + 2 * ns + nh) + di * d + di  # in/out/conv-ish
        if self.is_moe:
            per_layer += self.num_experts * 3 * d * self.d_ff
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff
        per_layer += 2 * d                      # norms
        return emb + l * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_all = self.n_layers * self.num_experts * 3 * d * self.d_ff
        moe_active = self.n_layers * self.top_k * 3 * d * self.d_ff
        return full - moe_all + moe_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> Sequence[ShapeConfig]:
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        out.append(SHAPES["long_500k"])
    return out
