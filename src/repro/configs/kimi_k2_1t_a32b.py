"""Kimi K2: trillion-parameter MoE, 384 experts top-8. [arXiv:2501.kimi2; unverified]

1T params force the large-scale memory path: EP over model axis, FSDP storage
sharding over data, factored optimizer states. Expert dispatch = one d=9 pass.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab=163840, num_experts=384, top_k=8,
    rope_theta=5e4, optimizer="adafactor", fsdp_params=True, seq_shard_activations=True,
)
