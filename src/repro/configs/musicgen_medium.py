"""MusicGen-medium decoder over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a stub (token ids over vocab 2048);
codebook interleaving is out of scope. n_heads=24 is not divisible by the
16-way model axis -> attention weights replicate, FFN shards (see sharding).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048, frontend="audio_tokens",
    head_pad_to=32, kv_pad_to=32,
)
