"""InternVL2-26B: InternViT frontend (stub) + InternLM2-20B backbone.
[arXiv:2404.16821; hf]

input_specs() provides precomputed patch embeddings; the ViT is out of scope.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553, rope_theta=1e6,
    frontend="vision_patches", num_patches=256, fsdp_params=True,
)
