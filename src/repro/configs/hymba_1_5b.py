"""Hymba-1.5B: parallel attention+mamba heads per block. [arXiv:2411.13676; hf]

Sliding-window attention everywhere except 3 global layers + SSM state =>
sub-quadratic: runs the long_500k decode cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2,
    attn_window=1024, global_attn_layers=(0, 15, 31),
    supports_long_context=True,
)
