"""Paper Fig. 7: input-size sweep — hybrid vs LSD vs XLA sort crossover.

The paper: CUB has an edge below ~1.9M keys (constant overheads); the hybrid
sort wins above.  We report the same sweep on uniform and on the hybrid's
worst-case (zero-entropy) distribution.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import hybrid_sort, lsd_sort, default_config
from repro.data.distributions import entropy_keys, constant_keys
from benchmarks.common import timeit, row


def main(fast: bool = True):
    rng = np.random.default_rng(1)
    cfg = default_config(4)
    tops = 20 if fast else 22
    for logn in range(14, tops + 1, 2):
        n = 1 << logn
        for dist, x in (("uniform", entropy_keys(rng, n, 0)),
                        ("const", constant_keys(n, 7))):
            xj = jnp.asarray(x)
            t_h = timeit(lambda: hybrid_sort(xj, cfg=cfg))
            t_l = timeit(lambda: lsd_sort(xj, d=5))
            t_x = timeit(lambda: jnp.sort(xj))
            row(f"fig7/{dist}/n2^{logn}/hybrid", t_h * 1e6,
                f"rate={n/t_h/1e6:.1f}Mk/s")
            row(f"fig7/{dist}/n2^{logn}/lsd5", t_l * 1e6,
                f"rate={n/t_l/1e6:.1f}Mk/s speedup={t_l/t_h:.2f}")
            row(f"fig7/{dist}/n2^{logn}/xla", t_x * 1e6,
                f"rate={n/t_x/1e6:.1f}Mk/s")


if __name__ == "__main__":
    main(fast=False)
