"""Paper Fig. 8: chunked/pipelined out-of-core sorting, s = 1..16 chunks.

The distributed sort over 8 fake devices is the TPU analogue of the PCIe
pipeline: local sort / all_to_all exchange / merge, with chunk count s
controlling the overlap window.  Runs in a subprocess so the 8-device flag
never touches the parent process.  Reports wall-clock plus the paper's
pipeline model T = T_x/s + max(T_x, T_s, T_m) + ... as derived columns.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap

from benchmarks.common import row

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import time
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.distributed import make_distributed_sort

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n = int(sys.argv[1])
    x = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    for s in (1, 2, 4, 8, 16):
        fn = jax.jit(make_distributed_sort(mesh, "data", num_chunks=s))
        out = fn(x); jax.block_until_ready(out)      # compile+warm
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            ts.append(time.perf_counter() - t0)
        t = float(np.median(ts))
        print(f"RESULT s={s} t_us={t*1e6:.1f} rate={n/t/1e6:.2f}Mk/s")
""")


def main(fast: bool = True):
    n = 1 << 18 if fast else 1 << 21
    res = subprocess.run([sys.executable, "-c", SCRIPT, str(n)],
                         capture_output=True, text=True, timeout=1200)
    for line in res.stdout.splitlines():
        if line.startswith("RESULT"):
            parts = dict(p.split("=") for p in line.split()[1:])
            row(f"fig8/chunks{int(parts['s']):02d}", float(parts["t_us"]),
                f"rate={parts['rate']} n={n}")
    if "RESULT" not in res.stdout:
        row("fig8/error", 0.0, res.stderr[-200:].replace(",", ";"))


if __name__ == "__main__":
    main(fast=False)
