"""Paper Fig. 2: histogram throughput vs number of distinct digit values.

The GPU result: shared-memory atomics collapse to ~50% of peak when all keys
share one digit value; the thread-reduction trick restores it.  The TPU
one-hot/MXU histogram has *no* data-dependent contention — this benchmark
demonstrates that by sweeping distinct-value counts over (a) the scatter-add
formulation (the closest jnp analogue of atomics) and (b) the one-hot
contraction the Pallas kernel uses, plus the kernel itself in interpret mode
for correctness.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.histogram import radix_histogram
from repro.kernels.ref import radix_histogram_ref
from benchmarks.common import timeit, row


@jax.jit
def hist_scatter_add(digits):
    return jnp.zeros((256,), jnp.int32).at[digits].add(1)


@jax.jit
def hist_onehot_matmul(digits):
    onehot = (digits[:, None] == jnp.arange(256, dtype=jnp.int32)[None, :])
    return jnp.ones((1, digits.shape[0]), jnp.int32) @ onehot.astype(jnp.int32)


def main(fast: bool = True):
    n = 1 << 18 if fast else 1 << 22
    rng = np.random.default_rng(0)
    for q in (1, 2, 4, 16, 64, 256):          # distinct digit values
        digits = jnp.asarray(rng.integers(0, q, n).astype(np.int32))
        t_sc = timeit(hist_scatter_add, digits)
        t_oh = timeit(hist_onehot_matmul, digits)
        row(f"fig2/q{q:03d}/scatter_add", t_sc * 1e6,
            f"rate={n/t_sc/1e9:.2f}Gk/s")
        row(f"fig2/q{q:03d}/onehot_mxu", t_oh * 1e6,
            f"rate={n/t_oh/1e9:.2f}Gk/s contention_free=1")
    # kernel correctness on the skewiest case
    keys = jnp.asarray(rng.integers(0, 2, 4096, dtype=np.uint32).reshape(4, 1024))
    got = radix_histogram(keys, 0, 8, interpret=True)
    ok = bool(jnp.array_equal(got, radix_histogram_ref(keys, 0, 8)))
    row("fig2/kernel_interp_check", 0.0, f"match={ok}")


if __name__ == "__main__":
    main(fast=False)
