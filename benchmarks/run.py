"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses paper-scale sizes
(slow on one CPU core); the default is a reduced but structurally identical
sweep.  ``python -m benchmarks.run [--full] [--only fig6,...]``
"""
from __future__ import annotations

import argparse
import sys
import traceback


MODULES = ["fig2_histogram", "fig6_entropy", "fig7_sizes", "fig8_pipeline",
           "fig10_latest", "ablations", "model_table", "moe_dispatch",
           "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    for name in MODULES:
        if only and not any(name.startswith(o) for o in only):
            continue
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(fast=not args.full)
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{name}/ERROR,0.0,{type(e).__name__}")


if __name__ == "__main__":
    main()
