"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses paper-scale sizes
(slow on one CPU core); the default is a reduced but structurally identical
sweep.  ``--json [PATH]`` additionally runs the engine-comparison sweep
(argsort vs Pallas kernel engine) and writes ``{name: us_per_call}`` to PATH
(default ``BENCH_hybrid.json``) so the perf trajectory is machine-readable.

``python -m benchmarks.run [--full] [--only fig6,...] [--json [PATH]]``
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


MODULES = ["fig2_histogram", "fig6_entropy", "fig7_sizes", "fig8_pipeline",
           "fig10_latest", "ablations", "model_table", "moe_dispatch",
           "roofline", "engines"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const="BENCH_hybrid.json",
                    default=None, metavar="PATH",
                    help="write the engine-sweep rows to PATH as JSON")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    for name in MODULES:
        if only and not any(name.startswith(o) for o in only):
            continue
        if name == "engines" and args.json is not None:
            continue                     # ran below; don't time it twice
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(fast=not args.full)
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{name}/ERROR,0.0,{type(e).__name__}")

    if args.json is not None:
        from benchmarks import engines
        rows = engines.main(fast=not args.full)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
