"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses paper-scale sizes
(slow on one CPU core); the default is a reduced but structurally identical
sweep; ``--smoke`` shrinks the engine sweep to a CI-sized single point.
``--json [PATH]`` additionally runs the engine-comparison sweep (argsort vs
fused Pallas kernel engine) and writes the rows to PATH (default
``BENCH_hybrid.json``) so the perf trajectory is machine-readable.  The JSON
is self-interpreting: alongside the raw ``{name: us_per_call}`` rows it
carries ``ratios/...`` speedup entries (argsort / kernel, > 1 means the
kernel engine wins) and a ``notes`` list that is non-empty whenever the
kernel engine regresses below the argsort baseline.

``--ooc`` adds the §5 out-of-core sweep (chunked kernel-engine pipeline +
streaming k-way merge vs one-shot argsort, ``benchmarks.ooc``); with
``--json PATH`` its rows land in ``BENCH_ooc.json`` next to PATH, carrying
the same ``ratios/...`` + ``notes`` contract.  ``--spill`` extends the ooc
sweep with the host-spill regime rows (streamed merge through bounded
device slabs vs device-resident merge vs one-shot argsort).  ``--faults``
adds the resilience-overhead rows (plain vs checksummed+checkpointed vs
injected-fault spill runs, gated ≤ 1.15x on the fault-free path).

``--dist`` adds the distributed-exchange sweep (``benchmarks.dist``): the
§5 shard exchange vs one-shot ``hybrid_sort`` per simulated device count
(fake-device subprocesses); with ``--json PATH`` its rows land in
``BENCH_dist.json`` next to PATH, devices × n × distribution with the same
``ratios/...`` + ``ratio_convention`` + ``notes`` contract.

``--entropy`` adds the entropy-ladder sweep (``benchmarks.entropy``):
adaptive vs static kernel-engine times plus executed-vs-nominal pass counts
per Thearling rung, as ``entropy/...`` rows merged into the same
BENCH_hybrid.json (``ratios/entropy/.../adaptive`` > 1 means pass elision
pays; the gate is >= 1.3x on low-entropy rungs, <= 1.05x regression on
uniform).

``python -m benchmarks.run [--full] [--smoke] [--only fig6,...]
                           [--json [PATH]] [--entropy] [--ooc] [--spill]
                           [--faults] [--dist]``
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


MODULES = ["fig2_histogram", "fig6_entropy", "fig7_sizes", "fig8_pipeline",
           "fig10_latest", "ablations", "model_table", "moe_dispatch",
           "roofline", "engines"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: engine sweep only, one small size")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const="BENCH_hybrid.json",
                    default=None, metavar="PATH",
                    help="write the engine-sweep rows to PATH as JSON")
    ap.add_argument("--entropy", action="store_true",
                    help="also run the entropy-ladder adaptive-vs-static "
                         "sweep (entropy/... rows in BENCH_hybrid.json)")
    ap.add_argument("--ooc", action="store_true",
                    help="also run the out-of-core sweep (BENCH_ooc.json)")
    ap.add_argument("--spill", action="store_true",
                    help="with --ooc: add the host-spill streamed-merge rows")
    ap.add_argument("--faults", action="store_true",
                    help="with --ooc: add the resilience-overhead rows "
                         "(checksums + checkpoints vs plain spill)")
    ap.add_argument("--dist", action="store_true",
                    help="also run the distributed-exchange device-scaling "
                         "sweep (BENCH_dist.json)")
    args = ap.parse_args()
    if args.spill and not args.ooc:
        ap.error("--spill extends the out-of-core sweep: pass --ooc too")
    if args.faults and not args.ooc:
        ap.error("--faults extends the out-of-core sweep: pass --ooc too")
    only = args.only.split(",") if args.only else None
    if args.smoke and only is None:
        only = ["engines"]               # smoke: the acceptance-gated sweep

    print("name,us_per_call,derived")
    for name in MODULES:
        if only and not any(name.startswith(o) for o in only):
            continue
        if name == "engines" and args.json is not None:
            continue                     # ran below; don't time it twice
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            if name == "engines":
                mod.main(fast=not args.full, smoke=args.smoke)
            else:
                mod.main(fast=not args.full)
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{name}/ERROR,0.0,{type(e).__name__}")

    def dump(rows, path):
        with open(path, "w") as f:
            json.dump(rows, f, indent=2, sort_keys=True)
        print(f"# wrote {path}", file=sys.stderr)
        if rows["notes"]:
            print(f"# {len(rows['notes'])} regression note(s) in {path}",
                  file=sys.stderr)

    if args.json is not None:
        from benchmarks import engines
        baseline = None
        if os.path.exists(args.json):        # previous sweep = the baseline:
            with open(args.json) as f:       # ratio deltas land in `notes`
                baseline = json.load(f)
        rows = engines.main(fast=not args.full, smoke=args.smoke,
                            baseline=baseline)
        if args.entropy:
            from benchmarks import entropy
            rows = entropy.main(fast=not args.full, smoke=args.smoke,
                                rows=rows)
        dump(rows, args.json)
    elif args.entropy:
        from benchmarks import entropy
        entropy.main(fast=not args.full, smoke=args.smoke)

    if args.ooc:
        from benchmarks import ooc
        rows = ooc.main(fast=not args.full, smoke=args.smoke,
                        spill=args.spill, faults=args.faults)
        if args.json is not None:
            dump(rows, os.path.join(os.path.dirname(args.json) or ".",
                                    "BENCH_ooc.json"))

    if args.dist:
        from benchmarks import dist
        rows = dist.main(fast=not args.full, smoke=args.smoke)
        if args.json is not None:
            dump(rows, os.path.join(os.path.dirname(args.json) or ".",
                                    "BENCH_dist.json"))


if __name__ == "__main__":
    main()
