"""Roofline table generator: reads artifacts/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (one row per arch x shape x mesh)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row


def load(out_dir: str = "artifacts/dryrun"):
    arts = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if path.endswith("summary.json"):
            continue
        with open(path) as f:
            arts.append(json.load(f))
    return arts


def markdown_table(arts, mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | step | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
        "bottleneck | useful/HLO flops | MFU bound | GiB/chip | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in arts:
        if a.get("mesh") != mesh:
            continue
        if not a.get("ok"):
            lines.append(f"| {a['arch']} | {a['shape']} | - | - | - | - | "
                         f"{a.get('skipped', a.get('error', '?'))[:40]} | - | - | - | - |")
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['step']} "
            f"| {a['t_compute_s']*1e3:.2f} | {a['t_memory_s']*1e3:.2f} "
            f"| {a['t_collective_s']*1e3:.2f} | {a['bottleneck']} "
            f"| {a['useful_flops_frac']:.3f} | {a['mfu_bound']*100:.1f}% "
            f"| {a['mem_per_chip_gib']:.2f} | {'y' if a['fits_16gib'] else 'N'} |")
    return "\n".join(lines)


def main(fast: bool = True):
    arts = load()
    if not arts:
        row("roofline/missing", 0.0, "run python -m repro.launch.dryrun --all first")
        return
    for a in arts:
        if not a.get("ok"):
            row(f"roofline/{a['mesh']}/{a['arch']}/{a['shape']}", 0.0,
                f"SKIP:{a.get('skipped', a.get('error','?'))[:50]}".replace(",", ";"))
            continue
        row(f"roofline/{a['mesh']}/{a['arch']}/{a['shape']}",
            a["t_bound_s"] * 1e6 if "t_bound_s" in a else
            max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"]) * 1e6,
            f"bottleneck={a['bottleneck']} mfu_bound={a['mfu_bound']*100:.1f}% "
            f"mem={a['mem_per_chip_gib']:.2f}GiB fits={int(a['fits_16gib'])}")


if __name__ == "__main__":
    main()
    print()
    print(markdown_table(load(), "pod"))
