"""Distributed-exchange sweep: devices × n × distribution (BENCH_dist.json).

Times the §5 shard-exchange pipeline (``core.distributed.
make_distributed_sort``: local sorts → sampled splitters → one fused
bucketing pass per shard → capacity-padded all_to_all → high-fan-in merge)
against one-shot ``hybrid_sort`` over the same global array, per simulated
device count — the pod-scale scaling row the ROADMAP item asks for.  Each
device count runs in its own subprocess under
``--xla_force_host_platform_device_count=N`` (fake host devices; the flag
must precede jax init and never touch the parent).

Rows: ``dist/sort/n=<n>/dev=<N>/<dist>/{hybrid,dist}``;
``engines.annotate`` stamps ``ratio_convention`` and
``ratios/dist/sort/.../dist`` = hybrid_us / dist_us (> 1 = the exchange
beats the one-shot sort).  On this CPU container the fake-device all_to_all
is memcpy through shared memory, so the tracked signal is the scaling
*shape* (per-shard work shrinks as 1/N while exchange volume stays 2·n·b)
plus the structural gates in tests/test_launch_count.py, not absolute wins.
Both contenders run the argsort engine so interpret-mode kernel overhead
does not drown the exchange term.

``python -m benchmarks.dist [--smoke|--full] [--out PATH]`` writes
BENCH_dist.json directly (the ``scripts/ci.sh dist`` entry);
``python -m benchmarks.run --dist --json ...`` routes through here too.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import row
from benchmarks.engines import annotate

DISTS = ("uniform", "zipf", "clustered")

SCRIPT = textwrap.dedent("""
    import json, sys, time
    sys.path.insert(0, "src")
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.distributed import make_distributed_sort
    from repro.core.hybrid import hybrid_sort
    from repro.data.distributions import (clustered_keys, entropy_keys,
                                          zipf_keys)

    cfg = json.loads(sys.argv[1])
    ndev = jax.device_count()
    assert ndev == cfg["ndev"], (ndev, cfg["ndev"])
    mesh = jax.make_mesh((ndev,), ("data",))
    GEN = {"uniform": lambda seed, n: entropy_keys(seed, n, 0),
           "zipf": lambda seed, n: zipf_keys(seed, n, a=1.2),
           "clustered": lambda seed, n: clustered_keys(seed, n, clusters=64)}

    def timeit(fn, x, iters=3):
        jax.block_until_ready(fn(x))                     # compile + warm
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    for n in cfg["ns"]:
        dfn = jax.jit(make_distributed_sort(mesh, "data", engine="argsort"))
        hfn = jax.jit(lambda a: hybrid_sort(a, engine="argsort"))
        for seed, dist in enumerate(cfg["dists"]):
            x = jnp.asarray(GEN[dist](seed, n))
            stem = f"dist/sort/n={n}/dev={ndev}/{dist}"
            print(f"ROW {stem}/hybrid {timeit(hfn, x) * 1e6:.3f}", flush=True)
            print(f"ROW {stem}/dist {timeit(dfn, x) * 1e6:.3f}", flush=True)
""")


def _collect_ndev(ndev: int, ns, dists) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    cfg = json.dumps({"ndev": ndev, "ns": list(ns), "dists": list(dists)})
    res = subprocess.run([sys.executable, "-c", SCRIPT, cfg], env=env,
                         capture_output=True, text=True, timeout=3600)
    out = {}
    for line in res.stdout.splitlines():
        if line.startswith("ROW "):
            _, name, us = line.split()
            out[name] = float(us)
    if not out:
        raise RuntimeError(
            f"dist sweep produced no rows at ndev={ndev}:\n"
            f"{res.stderr[-2000:]}")
    return out


def collect(fast: bool = True, smoke: bool = False) -> dict:
    if smoke:
        ndevs, ns, dists = (8,), (1 << 12,), ("uniform",)
    elif fast:
        ndevs, ns, dists = (2, 8), (1 << 14,), DISTS
    else:
        ndevs, ns, dists = (2, 8, 16, 48), (1 << 16,), DISTS
    out = {}
    for ndev in ndevs:
        out.update(_collect_ndev(ndev, ns, dists))
    return annotate(out, baseline="hybrid", contender="dist")


def main(fast: bool = True, smoke: bool = False) -> dict:
    rows = collect(fast, smoke=smoke)
    for name, us in rows.items():
        if not isinstance(us, float):        # notes, ratio_convention
            continue
        if name.startswith("ratios/"):
            row(name, 0.0, f"{us:.3f}x-hybrid-over-dist")
            continue
        n = int(name.split("n=")[1].split("/")[0])
        row(name, us, f"{1e3 * us / n:.2f}ns/key")
    for note in rows["notes"]:
        print(f"# WARNING {note}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_dist.json")
    args = ap.parse_args()
    rows = main(fast=not args.full, smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}", file=sys.stderr)
