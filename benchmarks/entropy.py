"""Entropy ladder: adaptive vs static kernel-engine sweep (``entropy/...``).

Times ``hybrid_sort(engine="kernel")`` with the entropy-adaptive schedule on
(the default: static live-bit narrowing + mid-sort elision of
single-occupied-digit passes) against the same kernel forced to the full
nominal ⌈k/d⌉ schedule (``adaptive=False``), across the Thearling entropy
ladder plus the §5 skew shapes (clustered, shared-prefix, constant).

Every rung contributes four rows to BENCH_hybrid.json:

  ``entropy/<rung>/n=<n>/static``           nominal-schedule kernel time (us)
  ``entropy/<rung>/n=<n>/adaptive``         adaptive-schedule kernel time (us)
  ``entropy/<rung>/n=<n>/passes_executed``  counting passes actually launched
  ``entropy/<rung>/n=<n>/passes_nominal``   the ⌈k/d⌉ the static kernel runs

plus the ``ratios/entropy/.../adaptive`` speedup rows from
``engines.annotate`` (static_us / adaptive_us — > 1 means elision pays; the
``ratio_convention`` field pins the orientation).  The perf gate: >= 1.3x on
the low-entropy rungs ``clustered4`` / ``prefix16`` / ``constant`` (dead or
single-occupied digit positions the static schedule still launches passes
for), <= 1.05x slowdown on uniform keys (where the only adaptive cost is
the skip predicate reading the histogram the fused pass already produced).
The Thearling AND rungs (``ands1``/``ands3``) sit near 1.0x by design:
AND-ed keys keep every bit position live, so their pass-count savings come
from the pre-existing done-bucket early exit, which the static schedule
shares — the rows document that boundary.  Pass-count *correctness*
(executed < nominal, census-gated) lives in tests/test_adaptive.py — these
rows track the speed.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import timeit, row
from benchmarks.engines import annotate
from repro.core import SortConfig, hybrid_sort
from repro.core import model as sort_model
from repro.data.distributions import clustered_keys, constant_keys, \
    entropy_keys

# the engine sweep's config (benchmarks.engines.CFG): multi-block segments,
# interpret-tractable tiles
CFG = SortConfig(d=8, kpb=256, local_threshold=768, merge_threshold=512)


def _rungs(rng, n):
    """(name, keys) per rung, uniform first — the no-regression anchor.

    ``clustered4`` is the mid-sort-elision showcase: 4 clusters aligned to
    the top digit with live low bytes, so the segments stay above the
    local-sort threshold while two whole digit positions are constant —
    the static schedule launches passes over them, the adaptive one elides
    them off the fused launch's free next-pass histogram.
    """
    prefix = (jnp.full((n,), 0xABCD << 16, jnp.uint32)
              | jnp.asarray(rng.integers(0, 1 << 16, n).astype(np.uint32)))
    clustered4 = jnp.asarray(
        (rng.integers(0, 4, n).astype(np.uint32) << np.uint32(24))
        | rng.integers(0, 1 << 8, n).astype(np.uint32))
    return [
        ("uniform", jnp.asarray(entropy_keys(rng, n, 0))),
        ("ands1", jnp.asarray(entropy_keys(rng, n, 1))),
        ("ands3", jnp.asarray(entropy_keys(rng, n, 3))),
        ("clustered", jnp.asarray(clustered_keys(rng, n))),
        ("clustered4", clustered4),
        ("prefix16", prefix),
        ("constant", jnp.asarray(constant_keys(n))),
    ]


def collect(fast: bool = True, smoke: bool = False) -> dict:
    if smoke:
        sizes = [1 << 10]
    elif fast:
        sizes = [1 << 14]                # the acceptance-gated size (16384)
    else:
        sizes = [1 << 14, 1 << 16]
    rng = np.random.default_rng(0)
    out = {}
    for n in sizes:
        nominal = sort_model.num_digits(32, CFG.d)
        for name, x in _rungs(rng, n):
            t_s = timeit(lambda a: hybrid_sort(a, cfg=CFG, engine="kernel",
                                               adaptive=False), x) * 1e6
            t_a = timeit(lambda a: hybrid_sort(a, cfg=CFG,
                                               engine="kernel"), x) * 1e6
            _, st = hybrid_sort(x, cfg=CFG, engine="kernel",
                                return_stats=True)
            stem = f"entropy/{name}/n={n}"
            out[f"{stem}/static"] = t_s
            out[f"{stem}/adaptive"] = t_a
            out[f"{stem}/passes_executed"] = float(st.counting_passes)
            out[f"{stem}/passes_nominal"] = float(nominal)
    return annotate(out, baseline="static", contender="adaptive")


def main(fast: bool = True, smoke: bool = False, rows: dict = None) -> dict:
    """Run the sweep; merge into ``rows`` (the engine-sweep dict) if given."""
    swept = collect(fast, smoke=smoke)
    for name, us in swept.items():
        if not isinstance(us, float):    # notes, ratio_convention
            continue
        if name.startswith("ratios/"):
            row(name, 0.0, f"{us:.3f}x-static-over-adaptive")
        elif name.endswith(("/passes_executed", "/passes_nominal")):
            row(name, 0.0, f"{us:.0f}passes")
        else:
            n = int(name.split("n=")[1].split("/")[0])
            row(name, us, f"{1e3 * us / n:.2f}ns/key")
    for note in swept["notes"]:
        print(f"# WARNING {note}")
    if rows is not None:
        notes = rows.get("notes", []) + swept.pop("notes")
        rows.update(swept)
        rows["notes"] = notes
        return rows
    return swept
