"""Paper Fig. 6: sorting rate vs key-distribution entropy.

Hybrid radix sort vs the LSD baseline (CUB proxy, d=5; pass --lsd-bits 7 for
the CUB-1.6.4 appendix variant) vs XLA's built-in sort, across the Thearling
entropy ladder (uniform -> constant), for 32-bit keys and 32/32 pairs.

Derived columns report the *memory-traffic model* for the FUSED engine
(ROADMAP §4.3 table): each executed counting pass touches keys and values
twice (1R+1W — pass i's scatter computes pass i+1's histogram for free),
plus ONE prologue histogram read of the keys, plus local-sort 2 touches —
the quantity the paper's speedup is built on — and the implied time on the
TPU target (819 GB/s HBM).  The ``adaptive`` column reports executed vs
nominal ⌈k/d⌉ pass counts (entropy-adaptive elision); the LSD baseline is
timed with ``adaptive=False`` so it stays the CUB proxy.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import hybrid_sort, lsd_sort, SortConfig, default_config
from repro.core import model as sort_model
from repro.data.distributions import entropy_keys, ENTROPY_BITS_32
from benchmarks.common import timeit, row

from repro.utils.roofline import HBM_BW


def traffic_model_bytes(n, key_bytes, passes, local_sorted, value_bytes=0):
    """Fused-engine key/value bytes: ``(2·p + 1)·n·b_k + 2·p·n·b_v`` + local.

    Each executed pass reads and writes every key/value ONCE (the fused
    launch folds the next pass's histogram into the scatter, §4.3); the
    ``+ 1`` is pass 0's prologue histogram sweep over the keys.  The old
    unfused 3-touch formula overcharged every pass by one key read.
    """
    per_pass = n * 2 * (key_bytes + value_bytes)
    prologue = n * key_bytes if passes else 0
    local = n * 2 * (key_bytes + value_bytes) if local_sorted else 0
    return passes * per_pass + prologue + local


def run(n: int = 1 << 20, pairs: bool = False, lsd_bits: int = 5,
        ands_list=(0, 1, 2, 3, 6, 30)):
    rng = np.random.default_rng(0)
    cfg = default_config(4, 4 if pairs else 0)
    kind = "pairs32" if pairs else "keys32"
    nd_lsd = sort_model.num_digits(32, lsd_bits)
    for ands in ands_list:
        x = entropy_keys(rng, n, ands)
        vals = jnp.arange(n, dtype=jnp.int32) if pairs else None
        xj = jnp.asarray(x)

        def h_sort():
            out = hybrid_sort(xj, vals, cfg=cfg, return_stats=True)
            return out

        def l_sort():
            # adaptive=False: the baseline stays the CUB proxy's full
            # ⌈k/d⌉-pass schedule — elision is the contender's edge
            return lsd_sort(xj, vals, d=lsd_bits, adaptive=False)

        t_h = timeit(h_sort)
        t_l = timeit(l_sort)
        t_x = timeit(lambda: jnp.sort(xj))
        res = h_sort()
        stats = res[-1]
        passes = int(stats.counting_passes)
        elided = int(stats.elided_passes)
        nominal = sort_model.num_digits(32, cfg.d)
        local = bool(stats.used_local_sort)

        vb = 4 if pairs else 0
        hb = traffic_model_bytes(n, 4, passes, local, vb)
        lb = traffic_model_bytes(n, 4, nd_lsd, False, vb)
        ent = ENTROPY_BITS_32.get(ands, 0.0)
        row(f"fig6/{kind}/e{ent:05.2f}/hybrid", t_h * 1e6,
            f"passes={passes}+local={int(local)} model_traffic={hb/1e6:.0f}MB "
            f"tpu_time={hb/HBM_BW*1e3:.2f}ms rate={n/t_h/1e6:.1f}Mk/s")
        row(f"fig6/{kind}/e{ent:05.2f}/adaptive", 0.0,
            f"executed={passes} elided={elided} nominal={nominal} "
            f"(executed+elided <= nominal; elision is census-gated, "
            f"see tests/test_adaptive.py)")
        row(f"fig6/{kind}/e{ent:05.2f}/lsd{lsd_bits}", t_l * 1e6,
            f"passes={nd_lsd} model_traffic={lb/1e6:.0f}MB "
            f"tpu_time={lb/HBM_BW*1e3:.2f}ms rate={n/t_l/1e6:.1f}Mk/s")
        row(f"fig6/{kind}/e{ent:05.2f}/xla_sort", t_x * 1e6,
            f"rate={n/t_x/1e6:.1f}Mk/s")
        row(f"fig6/{kind}/e{ent:05.2f}/traffic_ratio", 0.0,
            f"lsd/hybrid={lb/hb:.3f} (paper expects >=1.6 uniform, ~1.75 32-bit)")


def main(fast: bool = True):
    run(n=1 << 18 if fast else 1 << 22, pairs=False)
    run(n=1 << 18 if fast else 1 << 22, pairs=True, ands_list=(0, 3))


if __name__ == "__main__":
    main(fast=False)
