"""Paper Appendix A (Fig. 10): comparison against the *latest* baselines.

CUB 1.6.4 raised the LSD digit width to 7 bits on some architectures — the
closest structural proxy is our LSD baseline with d=7 (5 passes for 32-bit
keys vs the hybrid's 4 + local-sort early exit).  The paper still reports a
1.29–1.56x hybrid advantage; the traffic model here shows why: the pass-count
gap narrows but the local sort's whole-pass savings on favourable
distributions remain.
"""
from __future__ import annotations

from benchmarks.fig6_entropy import run


def main(fast: bool = True):
    run(n=1 << 17 if fast else 1 << 21, pairs=False, lsd_bits=7,
        ands_list=(0, 3, 30))


if __name__ == "__main__":
    main(fast=False)
