"""Paper Figs. 11-14 (Appendix B): impact of switching optimisations off.

Toggles, on a skewed and a uniform distribution:
  * no bucket merging      (∂ = 1: every sub-bucket its own segment — R3 off)
  * single local-sort bin  (local-sort padding waste modelled: every done
    bucket pads to ∂̂ instead of its size class)
  * no local sort          (∂̂ = 1: every bucket runs all counting passes —
    isolates the local-sort early-exit win)
GPU-only toggles (look-ahead, thread-reduction) have no TPU analogue — their
function is subsumed by the contention-free kernels (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core import hybrid_sort, SortConfig, default_config
from repro.data.distributions import entropy_keys
from benchmarks.common import timeit, row


def main(fast: bool = True):
    n = 1 << 18 if fast else 1 << 21
    rng = np.random.default_rng(2)
    base = default_config(4)
    variants = {
        "baseline": base,
        "no_merge": dataclasses.replace(base, merge_threshold=1),
        "no_local_sort": SortConfig(d=8, kpb=base.kpb, local_threshold=1,
                                    merge_threshold=1),
    }
    for ands in (0, 3):
        x = jnp.asarray(entropy_keys(rng, n, ands))
        ref = None
        for name, cfg in variants.items():
            t = timeit(lambda c=cfg: hybrid_sort(x, cfg=c, return_stats=True))
            out, stats = hybrid_sort(x, cfg=cfg, return_stats=True)
            assert bool((out[1:] >= out[:-1]).all()), name
            if ref is None:
                ref = t
            row(f"ablate/ands{ands}/{name}", t * 1e6,
                f"passes={int(stats.counting_passes)} "
                f"local={int(bool(stats.used_local_sort))} "
                f"segs={int(stats.num_segments)} delta={(t-ref)/ref*100:+.1f}%")
        # single local-sort configuration: padded-work model (the kernel pads
        # every bucket row to ∂̂ instead of its size class)
        _, stats = hybrid_sort(x, cfg=base, return_stats=True)
        segs = max(int(stats.num_segments), 1)
        sizes = np.diff(np.searchsorted(
            np.asarray(out), np.arange(0)))  # placeholder; model below
        avg = n / segs
        pad_single = base.local_threshold / max(avg, 1)
        classes = [128, 256, 512, 1024, 2048, 4096, base.local_threshold]
        import bisect
        cls = classes[min(bisect.bisect_left(classes, avg), len(classes) - 1)]
        pad_binned = cls / max(avg, 1)
        row(f"ablate/ands{ands}/single_localsort_config", 0.0,
            f"padded_work_single={pad_single:.2f}x "
            f"padded_work_binned={pad_binned:.2f}x "
            f"(local-sort kernel rows pad to class width)")


if __name__ == "__main__":
    main(fast=False)
