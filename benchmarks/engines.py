"""Engine comparison sweep: hybrid_sort argsort vs kernel (BENCH_hybrid.json).

Times the full sort and a single counting pass (``max_passes=1``) for both
engines across a size sweep so the per-pass scaling is machine-readable:
the argsort engine's pass costs O(n log n) comparisons, the kernel engine's
is ONE fused Pallas launch moving O(n) bytes.  ``derived`` reports ns per
key — flat for O(n), growing with log n for the argsort engine (modulo
interpret-mode overhead on CPU).

``python -m benchmarks.run --json`` writes the collected rows plus derived
``ratios/...`` entries (argsort-time / kernel-time, > 1 means the kernel
engine wins) and a ``notes`` list that is non-empty whenever the kernel
engine regresses below the argsort baseline — so BENCH files are
self-interpreting.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import timeit, row
from repro.core import SortConfig, hybrid_sort

# modest tile/thresholds: big enough to exercise multi-block segments, small
# enough that interpret-mode Pallas stays tractable on one CPU core
CFG = SortConfig(d=8, kpb=256, local_threshold=768, merge_threshold=512)
ENGINES = ("argsort", "kernel")


def collect(fast: bool = True, smoke: bool = False) -> dict:
    if smoke:
        sizes = [1 << 10]
    elif fast:
        sizes = [1 << 12, 1 << 14]
    else:
        sizes = [1 << 14, 1 << 16, 1 << 18]
    rng = np.random.default_rng(0)
    out = {}
    for n in sizes:
        x = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
        for eng in ENGINES:
            us = timeit(lambda a, e=eng: hybrid_sort(a, cfg=CFG, engine=e),
                        x) * 1e6
            out[f"hybrid/sort/n={n}/{eng}"] = us
            us1 = timeit(lambda a, e=eng: hybrid_sort(a, cfg=CFG, engine=e,
                                                      max_passes=1), x) * 1e6
            out[f"hybrid/pass/n={n}/{eng}"] = us1
    return annotate(out)


#: THE ratio convention, embedded in every BENCH JSON so the files are
#: self-interpreting: a ratio row is baseline time over contender time, so
#: values > 1 mean the contender (the kernel engine unless the row name
#: carries a ``/<contender>`` suffix) is faster and values < 1 mean it is
#: slower — interpret-mode CPU runs sit below 1 by overhead, which the
#: ``notes`` entries spell out per row.
RATIO_CONVENTION = ("ratios/* = baseline_us / contender_us; > 1 means the "
                    "contender (kernel unless suffixed) is FASTER than the "
                    "baseline, < 1 means slower")


def annotate(rows: dict, baseline: str = "argsort",
             contender: str = "kernel") -> dict:
    """Add contender-vs-baseline speedup ratios and regression notes in place.

    ``ratios/<kind>/n=<n>`` = baseline_us / contender_us (> 1: contender
    faster, < 1: contender slower — pinned machine-readably by the
    ``ratio_convention`` field this function stamps into the rows; the
    ROADMAP's "> 1 = kernel wins" phrasing refers to this same orientation).
    Non-default contenders get a ``/<contender>`` suffix so several
    pairings coexist in one file.  ``notes`` is a list of human-readable
    warnings, non-empty whenever the contender engine is slower than the
    baseline it must eventually beat — the self-interpretation contract
    every BENCH file (BENCH_hybrid.json, BENCH_ooc.json) carries.  Repeated
    calls with different contenders extend ``notes`` rather than reset it.
    """
    ratios = {}
    notes = rows.get("notes", [])
    rows["ratio_convention"] = RATIO_CONVENTION
    suffix = "" if contender == "kernel" else f"/{contender}"
    for name, us in list(rows.items()):
        if not (isinstance(us, float) and name.endswith(f"/{baseline}")):
            continue
        kname = name[: -len(baseline)] + contender
        if kname not in rows:
            continue
        stem = name[: -len(f"/{baseline}")]
        ratio = us / rows[kname] if rows[kname] else float("inf")
        ratios[f"ratios/{stem}{suffix}"] = ratio
        if ratio < 1.0:
            notes.append(
                f"{stem}: {contender} engine {1.0 / ratio:.2f}x SLOWER than "
                f"{baseline} baseline ({contender} {rows[kname]:.0f}us vs "
                f"{baseline} {us:.0f}us)")
    rows.update(ratios)
    rows["notes"] = notes
    return rows


def baseline_delta_notes(rows: dict, baseline: dict) -> dict:
    """Record the ``ratios/...`` movement versus a previous BENCH file.

    For every ratio entry present in both sweeps, append a note with the
    old/new kernel-row times and the speedup of the kernel engine against
    its own previous self — the regression meter the per-PR perf gates read
    (e.g. "n=16384 kernel-sort row must improve >= 1.5x over the PR 4
    baseline").  Baselines are matched by row name, so re-running a sweep
    with different sizes only reports the overlap.
    """
    notes = rows.setdefault("notes", [])
    for name, ratio in sorted(rows.items()):
        if not name.startswith("ratios/") or name not in baseline:
            continue
        stem = name[len("ratios/"):]
        krow = f"{stem}/kernel"
        if krow in rows and krow in baseline and rows[krow]:
            speedup = baseline[krow] / rows[krow]
            notes.append(
                f"{name}: {baseline[name]:.3f} -> {ratio:.3f} "
                f"(kernel {baseline[krow]:.0f}us -> {rows[krow]:.0f}us, "
                f"{speedup:.2f}x vs previous baseline)")
    return rows


def main(fast: bool = True, smoke: bool = False, baseline: dict = None) -> dict:
    rows = collect(fast, smoke=smoke)
    if baseline:
        baseline_delta_notes(rows, baseline)
    for name, us in rows.items():
        if not isinstance(us, float):    # notes, ratio_convention
            continue
        if name.startswith("ratios/"):
            row(f"engines/{name}", 0.0, f"{us:.3f}x-argsort-over-kernel")
            continue
        n = int(name.split("n=")[1].split("/")[0])
        row(f"engines/{name}", us, f"{1e3 * us / n:.2f}ns/key")
    for note in rows["notes"]:
        print(f"# WARNING {note}")
    return rows