"""Engine comparison sweep: hybrid_sort argsort vs kernel (BENCH_hybrid.json).

Times the full sort and a single counting pass (``max_passes=1``) for both
engines across a size sweep so the per-pass scaling is machine-readable:
the argsort engine's pass costs O(n log n) comparisons, the kernel engine's
costs O(n) traffic.  ``derived`` reports ns per key — flat for O(n), growing
with log n for the argsort engine (modulo interpret-mode overhead on CPU).

``python -m benchmarks.run --json`` writes the collected rows to
``BENCH_hybrid.json`` as ``{name: us_per_call}``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import timeit, row
from repro.core import SortConfig, hybrid_sort

# modest tile/thresholds: big enough to exercise multi-block segments, small
# enough that interpret-mode Pallas stays tractable on one CPU core
CFG = SortConfig(d=8, kpb=256, local_threshold=768, merge_threshold=512)
ENGINES = ("argsort", "kernel")


def collect(fast: bool = True) -> dict:
    sizes = [1 << 12, 1 << 14] if fast else [1 << 14, 1 << 16, 1 << 18]
    rng = np.random.default_rng(0)
    out = {}
    for n in sizes:
        x = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
        for eng in ENGINES:
            us = timeit(lambda a, e=eng: hybrid_sort(a, cfg=CFG, engine=e),
                        x) * 1e6
            out[f"hybrid/sort/n={n}/{eng}"] = us
            us1 = timeit(lambda a, e=eng: hybrid_sort(a, cfg=CFG, engine=e,
                                                      max_passes=1), x) * 1e6
            out[f"hybrid/pass/n={n}/{eng}"] = us1
    return out


def main(fast: bool = True) -> dict:
    rows = collect(fast)
    for name, us in rows.items():
        n = int(name.split("n=")[1].split("/")[0])
        row(f"engines/{name}", us, f"{1e3 * us / n:.2f}ns/key")
    return rows
