"""Shared benchmark utilities: timing, CSV output, data generators."""
from __future__ import annotations

import time

import numpy as np
import jax


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall-clock seconds of fn(*args) (block_until_ready'd)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def entropy_label(ands: int) -> str:
    from repro.data.distributions import ENTROPY_BITS_32
    return f"{ENTROPY_BITS_32.get(ands, 0.0):.2f}b"
