"""Out-of-core sweep: chunked pipeline vs one-shot argsort (BENCH_ooc.json).

Times ``oocsort`` — chunked device runs under double-buffered staging plus
⌈log_K⌉ streaming merge rounds (§5) — against the one-shot ``jnp.argsort``
path on the same host round-trip (device_put, device sort, device_get), over
the §5 input distributions (uniform / zipf / clustered).  Per case two
pipeline rows are measured — ``/ooc-kernel`` (fused-kernel chunk sorts) and
``/ooc-argsort`` (XLA-sort chunk sorts; both share the staging + merge
kernel) — and ``engines.annotate`` attaches a ``ratios/...`` entry
(argsort_us / ooc_us, > 1 = the pipeline wins) and ``notes`` regression
warnings for EACH against the ``/argsort`` baseline.  On this CPU container
interpret-mode overhead dominates, so the tracked §5 roofline proxy is the
ratio trajectory plus the structural gates (one launch per merge round,
sort-free merge) enforced by the test wall.

``--spill`` adds the host-spill sweep: ``spill/...`` rows time the
**streamed merge** (host-resident runs through budget-bounded device slabs,
``/spill``) against the **device-resident merge** (``/device``) and the
one-shot ``/argsort`` baseline over the same distributions — both pipeline
rows share argsort-engine chunk sorts so the delta isolates the merge
regime.  ``engines.annotate`` attaches ``ratios/...`` and ``notes`` for each
contender, the same self-interpretation contract.

Every row draws its keys from an explicit per-row seed
(``data.distributions``), so rows replay bit-identically in isolation.

``python -m benchmarks.run --json --ooc [--spill]`` writes BENCH_ooc.json.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit, row
from benchmarks.engines import annotate
from repro.core import SortConfig
from repro.core.outofcore import oocsort
from repro.data.distributions import clustered_keys, entropy_keys, zipf_keys

# modest chunk-sort tile/thresholds: interpret-mode tractable on one core
CFG = SortConfig(d=8, kpb=256, local_threshold=768, merge_threshold=512)
KWAY = 4
TILE = 128

DISTS = {
    "uniform": lambda seed, n: entropy_keys(seed, n, 0),
    "zipf": lambda seed, n: zipf_keys(seed, n, a=1.2),
    "clustered": lambda seed, n: clustered_keys(seed, n, clusters=64),
}


def one_shot_argsort(x: np.ndarray) -> np.ndarray:
    """Baseline: same host->device->host round trip, one device sort."""
    return np.asarray(jnp.sort(jax.device_put(x)))


def collect(fast: bool = True, smoke: bool = False) -> dict:
    if smoke:
        cases = [(1 << 10, 1 << 8)]                    # 4 chunks, 1 round
        dists = ("uniform",)
    elif fast:
        cases = [(1 << 12, 1 << 10), (1 << 14, 1 << 11)]   # 4 / 8 chunks
        dists = ("uniform", "zipf", "clustered")
    else:
        cases = [(1 << 16, 1 << 13), (1 << 18, 1 << 15)]
        dists = ("uniform", "zipf", "clustered")
    out = {}
    for seed, (n, chunk) in enumerate(cases):
        for dist in dists:
            x = DISTS[dist](seed, n)
            stem = f"ooc/sort/n={n}/chunks={n // chunk}/{dist}"
            out[f"{stem}/argsort"] = timeit(one_shot_argsort, x) * 1e6
            for eng in ("kernel", "argsort"):
                out[f"{stem}/ooc-{eng}"] = timeit(
                    lambda a, e=eng: oocsort(a, chunk, cfg=CFG, engine=e,
                                             kway=KWAY, tile=TILE), x) * 1e6
    out = annotate(out, contender="ooc-kernel")
    return annotate(out, contender="ooc-argsort")


def collect_spill(fast: bool = True, smoke: bool = False) -> dict:
    """Streamed (host-spill) merge vs device-resident merge vs argsort."""
    if smoke:
        cases = [(1 << 10, 1 << 8, 1 << 7)]            # n, chunk, slab
        dists = ("uniform",)
    elif fast:
        cases = [(1 << 12, 1 << 9, 1 << 7), (1 << 14, 1 << 11, 1 << 9)]
        dists = ("uniform", "zipf", "clustered")
    else:
        cases = [(1 << 16, 1 << 13, 1 << 11), (1 << 18, 1 << 15, 1 << 12)]
        dists = ("uniform", "zipf", "clustered")
    out = {}
    for seed, (n, chunk, slab) in enumerate(cases):
        for dist in dists:
            x = DISTS[dist](seed, n)
            stem = f"spill/sort/n={n}/chunks={n // chunk}/slab={slab}/{dist}"
            out[f"{stem}/argsort"] = timeit(one_shot_argsort, x) * 1e6
            out[f"{stem}/device"] = timeit(
                lambda a: oocsort(a, chunk, engine="argsort", kway=KWAY,
                                  tile=TILE), x) * 1e6
            out[f"{stem}/spill"] = timeit(
                lambda a: oocsort(a, chunk, engine="argsort", kway=KWAY,
                                  tile=TILE, device_slab_elems=slab),
                x) * 1e6
    out = annotate(out, contender="spill")
    return annotate(out, contender="device")


def main(fast: bool = True, smoke: bool = False, spill: bool = False) -> dict:
    rows = collect(fast, smoke=smoke)
    if spill:
        srows = collect_spill(fast, smoke=smoke)
        notes = rows.pop("notes", []) + srows.pop("notes", [])
        rows.update(srows)
        rows["notes"] = notes
    for name, us in rows.items():
        if name == "notes":
            continue
        if name.startswith("ratios/"):
            row(f"ooc/{name}", 0.0, f"{us:.3f}x-argsort-over-ooc")
            continue
        n = int(name.split("n=")[1].split("/")[0])
        row(name, us, f"{1e3 * us / n:.2f}ns/key")
    for note in rows["notes"]:
        print(f"# WARNING {note}")
    return rows
