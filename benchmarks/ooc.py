"""Out-of-core sweep: chunked pipeline vs one-shot argsort (BENCH_ooc.json).

Times ``oocsort`` — chunked device runs under double-buffered staging plus
⌈log_K⌉ streaming merge rounds (§5) — against the one-shot ``jnp.argsort``
path on the same host round-trip (device_put, device sort, device_get), over
the §5 input distributions (uniform / zipf / clustered).  Per case two
pipeline rows are measured — ``/ooc-kernel`` (fused-kernel chunk sorts) and
``/ooc-argsort`` (XLA-sort chunk sorts; both share the staging + merge
kernel) — and ``engines.annotate`` attaches a ``ratios/...`` entry
(argsort_us / ooc_us, > 1 = the pipeline wins) and ``notes`` regression
warnings for EACH against the ``/argsort`` baseline.  On this CPU container
interpret-mode overhead dominates, so the tracked §5 roofline proxy is the
ratio trajectory plus the structural gates (one launch per merge round,
sort-free merge) enforced by the test wall.

``--spill`` adds the host-spill sweep: ``spill/...`` rows time the
**streamed merge** (host-resident runs through budget-bounded device slabs,
``/spill``) against the **device-resident merge** (``/device``) and the
one-shot ``/argsort`` baseline over the same distributions — both pipeline
rows share argsort-engine chunk sorts so the delta isolates the merge
regime.  ``engines.annotate`` attaches ``ratios/...`` and ``notes`` for each
contender, the same self-interpretation contract.

``--faults`` adds the resilience-overhead sweep: ``faults/...`` rows time
the spill pipeline **plain** (PR 5 call shape, no resilience), **resilient**
(checksums at every host crossing + round-granular checkpointing + a
zero-fault ``FaultPolicy`` — the fault-free overhead the ≤ 1.15x gate
guards; breaching it lands a warning in ``notes``), and **faulty**
(deterministic transient faults at the transfer sites, absorbed by bounded
retries — what recovery actually costs).  ``ratios/faults/...`` entries are
plain_us / resilient_us.

Every row draws its keys from an explicit per-row seed
(``data.distributions``), so rows replay bit-identically in isolation.

``python -m benchmarks.run --json --ooc [--spill] [--faults]`` writes
BENCH_ooc.json.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit, row
from benchmarks.engines import annotate
from repro.core import SortConfig
from repro.core.outofcore import oocsort
from repro.data.distributions import clustered_keys, entropy_keys, zipf_keys

# modest chunk-sort tile/thresholds: interpret-mode tractable on one core
CFG = SortConfig(d=8, kpb=256, local_threshold=768, merge_threshold=512)
KWAY = 4
TILE = 128

DISTS = {
    "uniform": lambda seed, n: entropy_keys(seed, n, 0),
    "zipf": lambda seed, n: zipf_keys(seed, n, a=1.2),
    "clustered": lambda seed, n: clustered_keys(seed, n, clusters=64),
}


def one_shot_argsort(x: np.ndarray) -> np.ndarray:
    """Baseline: same host->device->host round trip, one device sort."""
    return np.asarray(jnp.sort(jax.device_put(x)))


def collect(fast: bool = True, smoke: bool = False) -> dict:
    if smoke:
        cases = [(1 << 10, 1 << 8)]                    # 4 chunks, 1 round
        dists = ("uniform",)
    elif fast:
        cases = [(1 << 12, 1 << 10), (1 << 14, 1 << 11)]   # 4 / 8 chunks
        dists = ("uniform", "zipf", "clustered")
    else:
        cases = [(1 << 16, 1 << 13), (1 << 18, 1 << 15)]
        dists = ("uniform", "zipf", "clustered")
    out = {}
    for seed, (n, chunk) in enumerate(cases):
        for dist in dists:
            x = DISTS[dist](seed, n)
            stem = f"ooc/sort/n={n}/chunks={n // chunk}/{dist}"
            out[f"{stem}/argsort"] = timeit(one_shot_argsort, x) * 1e6
            for eng in ("kernel", "argsort"):
                out[f"{stem}/ooc-{eng}"] = timeit(
                    lambda a, e=eng: oocsort(a, chunk, cfg=CFG, engine=e,
                                             kway=KWAY, tile=TILE), x) * 1e6
    out = annotate(out, contender="ooc-kernel")
    return annotate(out, contender="ooc-argsort")


def collect_spill(fast: bool = True, smoke: bool = False) -> dict:
    """Streamed (host-spill) merge vs device-resident merge vs argsort."""
    if smoke:
        cases = [(1 << 10, 1 << 8, 1 << 7)]            # n, chunk, slab
        dists = ("uniform",)
    elif fast:
        cases = [(1 << 12, 1 << 9, 1 << 7), (1 << 14, 1 << 11, 1 << 9)]
        dists = ("uniform", "zipf", "clustered")
    else:
        cases = [(1 << 16, 1 << 13, 1 << 11), (1 << 18, 1 << 15, 1 << 12)]
        dists = ("uniform", "zipf", "clustered")
    out = {}
    for seed, (n, chunk, slab) in enumerate(cases):
        for dist in dists:
            x = DISTS[dist](seed, n)
            stem = f"spill/sort/n={n}/chunks={n // chunk}/slab={slab}/{dist}"
            out[f"{stem}/argsort"] = timeit(one_shot_argsort, x) * 1e6
            out[f"{stem}/device"] = timeit(
                lambda a: oocsort(a, chunk, engine="argsort", kway=KWAY,
                                  tile=TILE), x) * 1e6
            out[f"{stem}/spill"] = timeit(
                lambda a: oocsort(a, chunk, engine="argsort", kway=KWAY,
                                  tile=TILE, device_slab_elems=slab),
                x) * 1e6
    out = annotate(out, contender="spill")
    return annotate(out, contender="device")


FAULT_OVERHEAD_GATE = 1.15     # fault-free resilient path vs plain, max


def collect_faults(fast: bool = True, smoke: bool = False) -> dict:
    """Resilience overhead: plain vs checksums+checkpoints vs injected faults.

    All three contenders run the identical spill plan, so the deltas isolate
    what the resilience layer costs: ``resilient`` pays per-crossing
    checksums + per-round checkpoints under a zero-fault policy (gated
    ≤ ``FAULT_OVERHEAD_GATE``x vs ``plain``), ``faulty`` additionally pays
    deterministic transient faults at the transfer sites, absorbed by
    bounded retries.
    """
    import tempfile

    from repro.core.faults import FaultPolicy, RetryPolicy

    if smoke:
        cases = [(1 << 10, 1 << 8, 1 << 7)]            # n, chunk, slab
        dists = ("uniform",)
    elif fast:
        cases = [(1 << 12, 1 << 9, 1 << 7)]
        dists = ("uniform", "zipf")
    else:
        cases = [(1 << 14, 1 << 11, 1 << 9), (1 << 16, 1 << 13, 1 << 11)]
        dists = ("uniform", "zipf", "clustered")
    out = {}
    notes = []
    for seed, (n, chunk, slab) in enumerate(cases):
        for dist in dists:
            x = DISTS[dist](seed, n)
            stem = f"faults/sort/n={n}/chunks={n // chunk}/slab={slab}/{dist}"
            run = lambda a, **kw: oocsort(a, chunk, engine="argsort",
                                          kway=KWAY, tile=TILE,
                                          device_slab_elems=slab, **kw)
            out[f"{stem}/plain"] = timeit(run, x) * 1e6
            with tempfile.TemporaryDirectory() as ckpt:
                out[f"{stem}/resilient"] = timeit(
                    lambda a: run(a, faults=FaultPolicy(seed=seed),
                                  retry=RetryPolicy(),
                                  checkpoint_dir=ckpt), x) * 1e6
                faulty = FaultPolicy(seed=seed, rates={"slab_upload": 0.05,
                                                       "chunk_upload": 0.05,
                                                       "slab_download": 0.05})
                out[f"{stem}/faulty"] = timeit(
                    lambda a: run(a, faults=faulty,
                                  retry=RetryPolicy(max_retries=8),
                                  checkpoint_dir=ckpt), x) * 1e6
            ratio = out[f"{stem}/plain"] / out[f"{stem}/resilient"]
            out[f"ratios/{stem}/resilient"] = ratio
            overhead = 1.0 / ratio if ratio else float("inf")
            if overhead > FAULT_OVERHEAD_GATE:
                notes.append(
                    f"{stem}: fault-free resilient path {overhead:.2f}x "
                    f"plain spill (checksum+checkpoint overhead gate is "
                    f"{FAULT_OVERHEAD_GATE}x)")
    out["notes"] = notes
    return out


def main(fast: bool = True, smoke: bool = False, spill: bool = False,
         faults: bool = False) -> dict:
    rows = collect(fast, smoke=smoke)
    for enabled, extra in ((spill, collect_spill), (faults, collect_faults)):
        if enabled:
            srows = extra(fast, smoke=smoke)
            notes = rows.pop("notes", []) + srows.pop("notes", [])
            rows.update(srows)
            rows["notes"] = notes
    for name, us in rows.items():
        if not isinstance(us, float):    # notes, ratio_convention
            continue
        if name.startswith("ratios/faults/"):
            row(f"ooc/{name}", 0.0, f"{us:.3f}x-plain-over-resilient")
            continue
        if name.startswith("ratios/"):
            row(f"ooc/{name}", 0.0, f"{us:.3f}x-argsort-over-ooc")
            continue
        n = int(name.split("n=")[1].split("/")[0])
        row(name, us, f"{1e3 * us / n:.2f}ns/key")
    for note in rows["notes"]:
        print(f"# WARNING {note}")
    return rows
