"""Paper §4.5 analytical model + Table 3: memory bounds and expected speedups.

Checks the closed-form bounds against measured bucket counts and reports the
aux-memory budget for the paper's four configurations.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (hybrid_sort, memory_budget, expected_speedup,
                        default_config)
from repro.core import model as sort_model
from repro.data.distributions import entropy_keys
from benchmarks.common import row


def main(fast: bool = True):
    # Table 3 configurations: aux memory <= 5% of M1 and expected speedups
    for key_b, val_b in ((4, 0), (8, 0), (4, 4), (8, 8)):
        cfg = default_config(key_b, val_b)
        n = 2 * 1024**3 // (key_b + val_b)            # the paper's 2 GB input
        b = memory_budget(n, key_b * 8, cfg)
        row(f"model/t3_k{key_b*8}v{val_b*8}/aux_frac", 0.0,
            f"aux/M1={b['aux_over_m1']*100:.2f}% "
            f"(paper: <=5% for 32-bit config)")
        row(f"model/t3_k{key_b*8}v{val_b*8}/expected_speedup", 0.0,
            f"traffic_lsd5_over_hybrid8={expected_speedup(key_b*8, val_b):.3f}")

    # measured bucket counts vs bounds (I1/I3), small-n instrumented run
    rng = np.random.default_rng(0)
    n = 1 << 16 if fast else 1 << 20
    from repro.core import SortConfig
    cfg = SortConfig(d=8, kpb=256, local_threshold=192, merge_threshold=128)
    for ands in (0, 3):
        x = jnp.asarray(entropy_keys(rng, n, ands))
        _, stats = hybrid_sort(x, cfg=cfg, return_stats=True)
        bound = sort_model.max_total_buckets(n, cfg)
        row(f"model/bounds/ands{ands}", 0.0,
            f"segments={int(stats.num_segments)} I3_bound={bound} "
            f"holds={int(stats.num_segments) <= bound}")


if __name__ == "__main__":
    main(fast=False)
