"""Beyond-paper integration benchmark: sort-based vs dense MoE dispatch.

The paper's counting pass vs the GShard one-hot einsum, at qwen3-moe and
kimi-k2 routing shapes (scaled for CPU).  Derived column: the dispatch-side
memory-traffic model — dense dispatch writes a (T, E, C) mask against the
sort path's O(T) partition — the same 'fewer passes over memory' argument as
the paper's Fig. 6, applied to MoE.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.moe import init_moe, moe_layer
from benchmarks.common import timeit, row


def main(fast: bool = True):
    key = jax.random.PRNGKey(0)
    t = 1 << 10 if fast else 1 << 13
    for arch, experts, topk in (("qwen3_moe_30b_a3b", 128, 8),
                                ("kimi_k2_1t_a32b", 384, 8)):
        cfg = get_smoke_config(arch)
        cfg = dataclasses.replace(cfg, num_experts=experts, top_k=topk,
                                  d_ff=64)
        params = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(key, (1, t, cfg.d_model), jnp.float32)

        for disp in ("sort", "dense"):
            c = dataclasses.replace(cfg, moe_dispatch=disp)
            fn = jax.jit(lambda p, xx, c=c: moe_layer(p, xx, c)[0])
            tt = timeit(fn, params, x)
            cap = max(4, int(c.capacity_factor * t * topk / experts))
            mask_bytes = t * topk * experts * cap * 4 if disp == "dense" else 0
            sort_bytes = t * topk * 4 * 3
            row(f"moe/{arch}/{disp}", tt * 1e6,
                f"experts={experts} topk={topk} tokens={t} "
                f"dispatch_model_bytes={(mask_bytes or sort_bytes)/1e6:.1f}MB")


if __name__ == "__main__":
    main(fast=False)
