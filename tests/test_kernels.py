"""Per-kernel interpret-mode validation against the pure-jnp oracles,
sweeping shapes, dtypes, digit positions and distributions."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import plan
from repro.kernels import fused, ref
from repro.kernels.histogram import radix_histogram
from repro.kernels.multisplit import tile_multisplit
from repro.kernels.bitonic import (bitonic_sort_rows, bitonic_sort_rows_kv,
                                   bitonic_sort_rows_stable)
from repro.kernels.assigned import assigned_histogram
from repro.kernels.ops import segmented_local_sort, tile_histogram_pass
from conftest import entropy_keys


@pytest.mark.parametrize("t,kpb", [(1, 256), (4, 512), (7, 1024)])
@pytest.mark.parametrize("shift,width", [(24, 8), (0, 8), (8, 5), (28, 4)])
def test_histogram_kernel(rng, t, kpb, shift, width):
    keys = jnp.asarray(rng.integers(0, 2**32, (t, kpb), dtype=np.uint32))
    got = radix_histogram(keys, shift, width, interpret=True)
    want = ref.radix_histogram_ref(keys, shift, width)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).sum() == t * kpb


@pytest.mark.parametrize("ands", [0, 3, 30])     # uniform .. near-constant
def test_histogram_kernel_skew(rng, ands):
    x = entropy_keys(rng, 4096, ands).reshape(4, 1024)
    got = radix_histogram(jnp.asarray(x), 24, 8, interpret=True)
    want = ref.radix_histogram_ref(jnp.asarray(x), 24, 8)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t,kpb", [(1, 128), (3, 256), (2, 512)])
@pytest.mark.parametrize("shift,width", [(24, 8), (0, 8), (16, 6)])
def test_multisplit_kernel(rng, t, kpb, shift, width):
    keys = jnp.asarray(rng.integers(0, 2**32, (t, kpb), dtype=np.uint32))
    sk, sd, rk, h = tile_multisplit(keys, shift, width, 32, interpret=True)
    rsk, rsd, rrk, rh = ref.tile_multisplit_ref(keys, shift, width)
    assert np.array_equal(np.asarray(h), np.asarray(rh))
    assert np.array_equal(np.asarray(sd), np.asarray(rsd))
    assert np.array_equal(np.asarray(rk), np.asarray(rrk))
    # key permutation: digit-major and a permutation of the input
    assert np.array_equal(np.sort(np.asarray(sk), axis=1),
                          np.sort(np.asarray(keys), axis=1))
    # within-tile stability: ref uses stable argsort; must match exactly
    assert np.array_equal(np.asarray(sk), np.asarray(rsk))


def test_multisplit_kernel_skewed(rng):
    x = entropy_keys(rng, 512, 8).reshape(2, 256)
    sk, sd, rk, h = tile_multisplit(jnp.asarray(x), 24, 8, 32, interpret=True)
    rsk, *_ = ref.tile_multisplit_ref(jnp.asarray(x), 24, 8)
    assert np.array_equal(np.asarray(sk), np.asarray(rsk))


@pytest.mark.parametrize("s,l", [(1, 64), (5, 128), (3, 1024)])
@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32])
def test_bitonic_kernel(rng, s, l, dtype):
    if np.issubdtype(dtype, np.floating):
        keys = rng.standard_normal((s, l)).astype(dtype)
    else:
        info = np.iinfo(dtype)
        keys = rng.integers(info.min, info.max, (s, l)).astype(dtype)
    got = bitonic_sort_rows(jnp.asarray(keys), interpret=True)
    assert np.array_equal(np.asarray(got), np.sort(keys, axis=1))


def test_bitonic_kv_kernel(rng):
    keys = rng.integers(0, 1000, (4, 256)).astype(np.uint32)   # duplicates
    vals = np.arange(4 * 256, dtype=np.int32).reshape(4, 256)
    ks, vs = bitonic_sort_rows_kv(jnp.asarray(keys), jnp.asarray(vals),
                                  interpret=True)
    ks, vs = np.asarray(ks), np.asarray(vs)
    assert np.array_equal(ks, np.sort(keys, axis=1))
    for i in range(4):                    # pair consistency, not stability
        assert np.array_equal(keys[i][vs[i] - i * 256], ks[i])


def test_assigned_histogram_scalar_prefetch(rng):
    keys = jnp.asarray(rng.integers(0, 2**32, (6, 256), dtype=np.uint32))
    # grid of 8 slots (static I4 bound), only 5 valid, out-of-order tiles
    tile_idx = jnp.asarray([3, 0, 5, 1, 4, 0, 0, 0], jnp.int32)
    valid = jnp.asarray([1, 1, 1, 1, 1, 0, 0, 0], jnp.int32)
    got = np.asarray(assigned_histogram(keys, tile_idx, valid, 24, 8,
                                        interpret=True))
    want = np.asarray(ref.radix_histogram_ref(keys, 24, 8))
    for g in range(8):
        if valid[g]:
            assert np.array_equal(got[g], want[int(tile_idx[g])]), g
        else:
            assert got[g].sum() == 0


def test_tile_histogram_pass_total(rng):
    x = rng.integers(0, 2**32, 5000, dtype=np.uint32)
    hist, total = tile_histogram_pass(jnp.asarray(x), 24, 8, kpb=1024)
    want = np.bincount((x >> 24) & 0xFF, minlength=256)
    assert np.array_equal(np.asarray(total), want)


def test_bitonic_stable_kernel_sentinel_safe(rng):
    """(key, idx) lexicographic sort: stable under duplicates, and real
    all-ones keys (== the padding sentinel) sort before pads (idx = n)."""
    keys = np.array([[0xFFFFFFFF, 5, 0xFFFFFFFF, 1, 5, 0xFFFFFFFF, 0, 2]],
                    np.uint32)
    idx = np.arange(8, dtype=np.int32).reshape(1, 8)
    sk, si = bitonic_sort_rows_stable(jnp.asarray(keys), jnp.asarray(idx),
                                      interpret=True)
    order = np.argsort(keys[0], kind="stable")
    assert np.array_equal(np.asarray(sk)[0], keys[0][order])
    assert np.array_equal(np.asarray(si)[0], order)    # full stability


def test_segmented_local_sort_done_flags(rng):
    """Only flagged segments are sorted; sentinel-colliding keys stay exact."""
    n = 1000
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    x[10] = x[600] = 0xFFFFFFFF                        # collide with pad value
    starts = jnp.asarray([0, 300, 640], jnp.int32)
    sizes = jnp.asarray([300, 340, 360], jnp.int32)
    flags = jnp.asarray([True, False, True])
    src, dst = segmented_local_sort(jnp.asarray(x), starts, sizes, flags, 512,
                                    interpret=True)
    s, d = np.asarray(src), np.asarray(dst)
    out = x.copy()
    m = d < n
    out[d[m]] = x[np.clip(s, 0, n - 1)[m]]
    want = x.copy()
    want[:300] = np.sort(x[:300])
    want[640:] = np.sort(x[640:])
    assert np.array_equal(out, want)


def test_segmented_local_sort_size_classes(rng):
    """The size-classed plan sorts byte-identically to the single worst-case
    table, while binning each bucket into the narrowest power-of-two row
    that fits it (§4.2's local sort configurations)."""
    from repro.kernels.ops import local_sort_class_plan

    n = 2000
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    x[5] = x[900] = 0xFFFFFFFF                  # collide with the pad value
    # ragged bucket sizes spanning several classes, incl. 0/1/oversized gaps
    sizes_np = np.array([3, 1, 60, 0, 500, 17, 130, 33, 256, n], np.int32)
    starts_np = np.concatenate([[0], np.cumsum(sizes_np)[:-1]]).astype(np.int32)
    sizes_np[-1] = n - starts_np[-1]            # tail bucket fills the rest
    flags_np = np.array([1, 1, 1, 1, 1, 0, 1, 1, 1, 1], bool)
    starts, sizes = jnp.asarray(starts_np), jnp.asarray(sizes_np)
    flags = jnp.asarray(flags_np)
    row_len = 1024

    def apply(src, dst):
        s, d = np.asarray(src), np.asarray(dst)
        out = x.copy()
        m = d < n
        out[d[m]] = x[np.clip(s, 0, n - 1)[m]]
        return out

    classes = local_sort_class_plan(n, row_len, s_max=len(sizes_np))
    got = apply(*segmented_local_sort(jnp.asarray(x), starts, sizes, flags,
                                      row_len, interpret=True,
                                      classes=classes))
    ref = apply(*segmented_local_sort(jnp.asarray(x), starts, sizes, flags,
                                      row_len, interpret=True))
    want = x.copy()
    for st_, sz, fl in zip(starts_np, sizes_np, flags_np):
        if fl and sz:
            want[st_:st_ + sz] = np.sort(x[st_:st_ + sz])
    assert np.array_equal(got, want)
    assert np.array_equal(got, ref)


def test_local_sort_class_plan_bounds():
    """Class widths double from min_len to row_len; capacities are the
    static counting bounds (class 0: every segment slot; class i: at most
    n // (L/2 + 1) + 1 buckets can exceed half the width)."""
    from repro.kernels.ops import local_sort_class_plan

    plan_ = local_sort_class_plan(16384, 1024, s_max=341, min_len=32)
    widths = [l for l, _ in plan_]
    assert widths == [32, 64, 128, 256, 512, 1024]
    assert plan_[0][1] == 341                   # class 0: bounded by s_max
    for l, rows in plan_[1:]:
        assert rows == min(341, 16384 // (l // 2 + 1) + 1)
    # degenerate: row_len below min_len collapses to one legacy-shaped class
    assert local_sort_class_plan(100, 16, s_max=9) == ((16, 9),)


# ------------------- fused counting pass (kernels/fused.py) -----------------

def _run_fused(x, bounds, n, kpb, sc, nsid, a_max, r, vals=(), batch=None):
    """Drive one fused launch over explicit segment bounds; returns the new
    [0, n) key buffer, new value buffers and the fused next-pass histogram."""
    lo = int(sc[0])
    width = int(sc[1])
    base = jnp.asarray([b for b, _ in bounds] + [n] * (a_max - len(bounds)),
                       jnp.int32)
    size = jnp.asarray([s for _, s in bounds] + [0] * (a_max - len(bounds)),
                       jnp.int32)
    hist = np.zeros((a_max, r), np.int32)
    for i, (b, s) in enumerate(bounds):
        digs = (x[b:b + s] >> lo) & ((1 << width) - 1)
        hist[i, :] = np.bincount(digs, minlength=r)
    base_excl = (base[:, None] +
                 jnp.cumsum(jnp.asarray(hist), axis=1) - jnp.asarray(hist))
    blocks = plan.make_region_blocks(base, size, n, kpb,
                                     plan.max_region_blocks(n, kpb, a_max),
                                     batch=batch)
    (ck, cv), (ak, av) = fused.make_ping_pong(jnp.asarray(x), vals, kpb)
    nk, nv, hist_next = fused.fused_counting_pass(
        ck, cv, ak, av, jnp.asarray(sc, jnp.int32), *blocks, base_excl,
        jnp.asarray(nsid, jnp.int32), kpb=kpb, r=r, a_max=a_max, n=n,
        interpret=True)
    return (np.asarray(nk)[:n], tuple(np.asarray(v)[:n] for v in nv),
            np.asarray(hist_next).reshape(a_max, r))


@pytest.mark.parametrize("batch", [None, 1, 3, 8])
def test_fused_pass_partitions_segments_and_copies_gaps(rng, batch):
    """One launch partitions every active segment in place (stably, by the
    scalar-windowed digit) and copies the done gaps through untouched —
    identically for flat descriptor rows and packed (G', B) super-steps
    (including a non-dividing B with masked tail rows)."""
    n = 3000
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    bounds = [(0, 700), (1000, 1300)]       # gaps: [700,1000) and [2300,3000)
    out, _, _ = _run_fused(x, bounds, n, 256, [0, 8, 8, 8],
                           np.full(2 * 256, 2), a_max=2, r=256, batch=batch)
    want = x.copy()
    for b, s in bounds:
        seg = x[b:b + s]
        want[b:b + s] = seg[np.argsort(seg & 0xFF, kind="stable")]
    assert np.array_equal(out, want)
    assert np.array_equal(out[700:1000], x[700:1000])     # gap untouched


@pytest.mark.parametrize("batch", [None, 4])
def test_fused_pass_values_ride_and_next_histogram(rng, batch):
    """Values ride the same scatter (§4.6) and the launch returns the NEXT
    pass's digit histogram for the flagged sub-buckets (§4.3 fusion), under
    flat and packed descriptor tables alike."""
    n = 2048
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    v = np.arange(n, dtype=np.int32)
    bounds = [(0, 2048)]
    # flag the digit-3 sub-bucket as next-pass active row 0 (rest: done)
    nsid = np.full(256, 1, np.int32)
    nsid[3] = 0
    out, (ov,), hist_next = _run_fused(
        x, bounds, n, 256, [8, 8, 0, 8], nsid, a_max=1, r=256,
        vals=(jnp.asarray(v),), batch=batch)
    p = np.argsort((x >> 8) & 0xFF, kind="stable")
    assert np.array_equal(out, x[p])
    assert np.array_equal(ov, v[p])
    picked = x[((x >> 8) & 0xFF) == 3]
    assert np.array_equal(hist_next[0],
                          np.bincount(picked & 0xFF, minlength=256))


def test_fused_pass_empty_and_partial_segments(rng):
    """Zero-size descriptor rows contribute no blocks; partial-width digits
    and non-KPB-aligned segments are exact."""
    n = 500
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    bounds = [(0, 200), (350, 130)]
    out, _, _ = _run_fused(x, bounds, n, 64, [2, 5, 0, 2],
                           np.full(4 * 32, 4), a_max=4, r=32)
    want = x.copy()
    for b, s in bounds:
        seg = x[b:b + s]
        want[b:b + s] = seg[np.argsort((seg >> 2) & 31, kind="stable")]
    assert np.array_equal(out, want)


def test_fused_full_lsd_sort_composed(rng):
    """End-to-end: a complete LSD radix sort built ONLY from fused passes
    (one launch per pass, histogram carried across passes) matches np.sort."""
    from repro.core import lsd_sort
    x = rng.integers(0, 2**32, 3000, dtype=np.uint32)
    got = np.asarray(lsd_sort(jnp.asarray(x), d=8, engine="kernel", kpb=512))
    assert np.array_equal(np.sort(x), got)


def test_fused_msd_first_pass_matches_partition(rng):
    """The fused engine's MSD top-digit pass equals a stable partition by the
    top byte (same permutation, same stability)."""
    n = 2048
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    out, _, _ = _run_fused(x, [(0, n)], n, 256, [24, 8, 16, 8],
                           np.full(256, 1), a_max=1, r=256)
    want = x[np.argsort((x >> 24) & 0xFF, kind="stable")]
    assert np.array_equal(out, want)


def test_make_region_blocks_table():
    """Region table: gaps interleave actives, every position in exactly one
    block, carry resets at region firsts, copy blocks flagged inactive."""
    base = jnp.asarray([100, 400, 600, 600], jnp.int32)   # 2 padding rows
    size = jnp.asarray([150, 100, 0, 0], jnp.int32)
    n, kpb = 600, 100
    rb = plan.make_region_blocks(base, size, n, kpb,
                                 plan.max_region_blocks(n, kpb, 4))
    seg = np.asarray(rb.seg)
    off = np.asarray(rb.offset)
    cnt = np.asarray(rb.count)
    act = np.asarray(rb.active)
    rst = np.asarray(rb.reset)
    live = cnt > 0
    # every key position covered exactly once
    covered = np.zeros(n, np.int32)
    for o, c in zip(off[live], cnt[live]):
        covered[o:o + c] += 1
    assert np.array_equal(covered, np.ones(n, np.int32))
    # active blocks carry their compact segment id, copies carry a_max
    for o, c, s, a in zip(off[live], cnt[live], seg[live], act[live]):
        inside_active = any(b <= o < b + sz for b, sz in [(100, 150), (400, 100)])
        assert bool(a) == inside_active, (o, c)
        if a:
            assert s == (0 if o < 400 else 1)
        else:
            assert s == 4
    # carry resets exactly at each region's first block
    firsts = {0, 100, 250, 400, 500}        # gap0, act0 (2 blocks), gap1, act1, gap2
    assert set(off[live][rst[live] == 1].tolist()) == firsts