"""Per-kernel interpret-mode validation against the pure-jnp oracles,
sweeping shapes, dtypes, digit positions and distributions."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.histogram import radix_histogram
from repro.kernels.multisplit import tile_multisplit
from repro.kernels.bitonic import (bitonic_sort_rows, bitonic_sort_rows_kv,
                                   bitonic_sort_rows_stable)
from repro.kernels.assigned import assigned_histogram, make_block_assignments
from repro.kernels.ops import (kernel_counting_pass, kernel_counting_pass_kv,
                               kernel_pass_perm, segmented_kernel_pass,
                               segmented_local_sort, tile_histogram_pass)
from conftest import entropy_keys


@pytest.mark.parametrize("t,kpb", [(1, 256), (4, 512), (7, 1024)])
@pytest.mark.parametrize("shift,width", [(24, 8), (0, 8), (8, 5), (28, 4)])
def test_histogram_kernel(rng, t, kpb, shift, width):
    keys = jnp.asarray(rng.integers(0, 2**32, (t, kpb), dtype=np.uint32))
    got = radix_histogram(keys, shift, width, interpret=True)
    want = ref.radix_histogram_ref(keys, shift, width)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).sum() == t * kpb


@pytest.mark.parametrize("ands", [0, 3, 30])     # uniform .. near-constant
def test_histogram_kernel_skew(rng, ands):
    x = entropy_keys(rng, 4096, ands).reshape(4, 1024)
    got = radix_histogram(jnp.asarray(x), 24, 8, interpret=True)
    want = ref.radix_histogram_ref(jnp.asarray(x), 24, 8)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t,kpb", [(1, 128), (3, 256), (2, 512)])
@pytest.mark.parametrize("shift,width", [(24, 8), (0, 8), (16, 6)])
def test_multisplit_kernel(rng, t, kpb, shift, width):
    keys = jnp.asarray(rng.integers(0, 2**32, (t, kpb), dtype=np.uint32))
    sk, sd, rk, h = tile_multisplit(keys, shift, width, 32, interpret=True)
    rsk, rsd, rrk, rh = ref.tile_multisplit_ref(keys, shift, width)
    assert np.array_equal(np.asarray(h), np.asarray(rh))
    assert np.array_equal(np.asarray(sd), np.asarray(rsd))
    assert np.array_equal(np.asarray(rk), np.asarray(rrk))
    # key permutation: digit-major and a permutation of the input
    assert np.array_equal(np.sort(np.asarray(sk), axis=1),
                          np.sort(np.asarray(keys), axis=1))
    # within-tile stability: ref uses stable argsort; must match exactly
    assert np.array_equal(np.asarray(sk), np.asarray(rsk))


def test_multisplit_kernel_skewed(rng):
    x = entropy_keys(rng, 512, 8).reshape(2, 256)
    sk, sd, rk, h = tile_multisplit(jnp.asarray(x), 24, 8, 32, interpret=True)
    rsk, *_ = ref.tile_multisplit_ref(jnp.asarray(x), 24, 8)
    assert np.array_equal(np.asarray(sk), np.asarray(rsk))


@pytest.mark.parametrize("s,l", [(1, 64), (5, 128), (3, 1024)])
@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32])
def test_bitonic_kernel(rng, s, l, dtype):
    if np.issubdtype(dtype, np.floating):
        keys = rng.standard_normal((s, l)).astype(dtype)
    else:
        info = np.iinfo(dtype)
        keys = rng.integers(info.min, info.max, (s, l)).astype(dtype)
    got = bitonic_sort_rows(jnp.asarray(keys), interpret=True)
    assert np.array_equal(np.asarray(got), np.sort(keys, axis=1))


def test_bitonic_kv_kernel(rng):
    keys = rng.integers(0, 1000, (4, 256)).astype(np.uint32)   # duplicates
    vals = np.arange(4 * 256, dtype=np.int32).reshape(4, 256)
    ks, vs = bitonic_sort_rows_kv(jnp.asarray(keys), jnp.asarray(vals),
                                  interpret=True)
    ks, vs = np.asarray(ks), np.asarray(vs)
    assert np.array_equal(ks, np.sort(keys, axis=1))
    for i in range(4):                    # pair consistency, not stability
        assert np.array_equal(keys[i][vs[i] - i * 256], ks[i])


def test_assigned_histogram_scalar_prefetch(rng):
    keys = jnp.asarray(rng.integers(0, 2**32, (6, 256), dtype=np.uint32))
    # grid of 8 slots (static I4 bound), only 5 valid, out-of-order tiles
    tile_idx = jnp.asarray([3, 0, 5, 1, 4, 0, 0, 0], jnp.int32)
    valid = jnp.asarray([1, 1, 1, 1, 1, 0, 0, 0], jnp.int32)
    got = np.asarray(assigned_histogram(keys, tile_idx, valid, 24, 8,
                                        interpret=True))
    want = np.asarray(ref.radix_histogram_ref(keys, 24, 8))
    for g in range(8):
        if valid[g]:
            assert np.array_equal(got[g], want[int(tile_idx[g])]), g
        else:
            assert got[g].sum() == 0


@pytest.mark.parametrize("n", [100, 1000, 4096, 10000])
@pytest.mark.parametrize("shift,width", [(24, 8), (0, 8), (27, 5)])
def test_kernel_counting_pass_matches_stable_partition(rng, n, shift, width):
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    got = np.asarray(kernel_counting_pass(jnp.asarray(x), shift, width, 32,
                                          kpb=512, interpret=True))
    digit = (x >> shift) & ((1 << width) - 1)
    want = x[np.argsort(digit, kind="stable")]
    assert np.array_equal(got, want)


def test_tile_histogram_pass_total(rng):
    x = rng.integers(0, 2**32, 5000, dtype=np.uint32)
    hist, total = tile_histogram_pass(jnp.asarray(x), 24, 8, kpb=1024)
    want = np.bincount((x >> 24) & 0xFF, minlength=256)
    assert np.array_equal(np.asarray(total), want)


def test_full_lsd_sort_composed_from_kernels(rng):
    """End-to-end: a complete LSD radix sort built ONLY from kernel passes
    (tile multisplit -> scanned offsets -> run copies) matches np.sort."""
    x = rng.integers(0, 2**32, 3000, dtype=np.uint32)
    keys = jnp.asarray(x)
    for p in range(4):                      # 4 x 8-bit LSD passes
        keys = kernel_counting_pass(keys, shift=8 * p, width=8, key_bits=32,
                                    kpb=512, interpret=True)
    assert np.array_equal(np.sort(x), np.asarray(keys))


def test_full_msd_first_pass_matches_hybrid(rng):
    """The kernel engine's MSD top-digit pass equals the jnp hybrid driver's
    first counting pass (same partition, same stability)."""
    from repro.core import to_ordered_bits
    x = rng.integers(0, 2**32, 2048, dtype=np.uint32)
    got = np.asarray(kernel_counting_pass(jnp.asarray(x), shift=24, width=8,
                                          key_bits=32, kpb=256, interpret=True))
    want = x[np.argsort((x >> 24) & 0xFF, kind="stable")]
    assert np.array_equal(got, want)


@pytest.mark.parametrize("vdtype", [np.uint32, np.int32])
def test_multisplit_kv_kernel(rng, vdtype):
    """§4.6 pairs path: values ride the same in-VMEM permutation as the keys."""
    from repro.kernels.multisplit import tile_multisplit_kv
    keys = rng.integers(0, 2**32, (3, 256), dtype=np.uint32)
    if vdtype == np.int32:
        vals = rng.integers(0, 2**31 - 1, (3, 256)).astype(vdtype)
    else:
        vals = rng.integers(0, 2**32, (3, 256), dtype=vdtype)
    sk, sv, sd, rk, h = tile_multisplit_kv(jnp.asarray(keys), jnp.asarray(vals),
                                           24, 8, 32, 32, interpret=True)
    rsk, rsd, rrk, rh = ref.tile_multisplit_ref(jnp.asarray(keys), 24, 8)
    assert np.array_equal(np.asarray(sk), np.asarray(rsk))
    assert np.array_equal(np.asarray(h), np.asarray(rh))
    # pair consistency per tile: value went wherever its key went
    for t in range(3):
        kmap = {(k, v) for k, v in zip(keys[t].tolist(), vals[t].tolist())}
        assert all((k, v) in kmap for k, v in
                   zip(np.asarray(sk)[t].tolist(), np.asarray(sv)[t].tolist()))


# --------------------- kernel-engine drivers (ops.py) -----------------------

@pytest.mark.parametrize("n", [100, 1000, 4096])
def test_kernel_counting_pass_kv_pairs(rng, n):
    """§4.6 pairs driver: values ride the multisplit permutation exactly."""
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    v = np.arange(n, dtype=np.int32)
    ok, ov = kernel_counting_pass_kv(jnp.asarray(x), jnp.asarray(v), 24, 8, 32,
                                     kpb=512, interpret=True)
    p = np.argsort((x >> 24) & 0xFF, kind="stable")
    assert np.array_equal(np.asarray(ok), x[p])
    assert np.array_equal(np.asarray(ov), v[p])


def test_kernel_pass_perm_full_lsd_with_pytree(rng):
    """(src, dst) run copies move an arbitrary payload pytree through a full
    LSD sort built only from kernel passes."""
    n = 2000
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    keys = jnp.asarray(x)
    vals = {"a": jnp.arange(n, dtype=jnp.int32),
            "b": jnp.arange(n, dtype=jnp.float32) * 0.5}
    import jax
    for p in range(4):
        src, dst = kernel_pass_perm(keys, shift=8 * p, width=8, key_bits=32,
                                    kpb=512, interpret=True)
        safe = jnp.clip(src, 0, n - 1)
        keys = jnp.zeros_like(keys).at[dst].set(keys[safe], mode="drop")
        vals = jax.tree.map(
            lambda v: jnp.zeros_like(v).at[dst].set(v[safe], mode="drop"), vals)
    assert np.array_equal(np.sort(x), np.asarray(keys))
    va = np.asarray(vals["a"])
    assert np.array_equal(x[va], np.sort(x))           # payload consistency
    assert np.array_equal(va.astype(np.float32) * 0.5, np.asarray(vals["b"]))


def test_segmented_kernel_pass_partitions_each_segment(rng):
    """The descriptor-driven pass partitions every segment in place, stably,
    and returns the per-segment histograms (M2)."""
    n = 3000
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    bounds = [(0, 700), (700, 300), (1700, 1300)]      # gap: [1000,1700) inactive
    seg_base = jnp.asarray([b for b, _ in bounds], jnp.int32)
    seg_size = jnp.asarray([s for _, s in bounds], jnp.int32)
    src, dst, seg_hist = segmented_kernel_pass(jnp.asarray(x), seg_base,
                                               seg_size, 8, 256, 20,
                                               interpret=True)
    s, d = np.asarray(src), np.asarray(dst)
    out = x.copy()
    m = d < n
    out[d[m]] = x[np.clip(s, 0, n - 1)[m]]
    want = x.copy()
    for b, sz in bounds:
        seg = x[b:b + sz]
        want[b:b + sz] = seg[np.argsort(seg & 0xFF, kind="stable")]
    assert np.array_equal(out, want)
    assert np.array_equal(out[1000:1700], x[1000:1700])   # gap untouched
    for i, (b, sz) in enumerate(bounds):
        assert np.array_equal(np.asarray(seg_hist)[i],
                              np.bincount(x[b:b + sz] & 0xFF, minlength=256))


def test_segmented_kernel_pass_empty_segments(rng):
    """Zero-size rows of the static descriptor table contribute no blocks."""
    n = 500
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    seg_base = jnp.asarray([0, 200, 200, 200], jnp.int32)
    seg_size = jnp.asarray([200, 0, 0, 300], jnp.int32)
    src, dst, _ = segmented_kernel_pass(jnp.asarray(x), seg_base, seg_size,
                                        8, 64, 12, interpret=True)
    s, d = np.asarray(src), np.asarray(dst)
    out = x.copy()
    m = d < n
    out[d[m]] = x[np.clip(s, 0, n - 1)[m]]
    want = x.copy()
    for b, sz in [(0, 200), (200, 300)]:
        seg = x[b:b + sz]
        want[b:b + sz] = seg[np.argsort(seg & 0xFF, kind="stable")]
    assert np.array_equal(out, want)


def test_make_block_assignments_table(rng):
    """Descriptor table: segment-major contiguous blocks, correct offsets,
    padding rows invalid (model I4)."""
    seg_base = jnp.asarray([0, 100, 400], jnp.int32)
    seg_size = jnp.asarray([100, 0, 130], jnp.int32)   # 2 + 0 + 3 blocks @ kpb=50
    ba = make_block_assignments(seg_base, seg_size, 50, 8)
    assert np.asarray(ba.valid).tolist() == [True] * 5 + [False] * 3
    assert np.asarray(ba.seg_idx)[:5].tolist() == [0, 0, 2, 2, 2]
    assert np.asarray(ba.key_offset)[:5].tolist() == [0, 50, 400, 450, 500]
    assert np.asarray(ba.blk_in_seg)[:5].tolist() == [0, 1, 0, 1, 2]
    assert np.asarray(ba.first_block)[:5].tolist() == [0, 0, 2, 2, 2]


def test_bitonic_stable_kernel_sentinel_safe(rng):
    """(key, idx) lexicographic sort: stable under duplicates, and real
    all-ones keys (== the padding sentinel) sort before pads (idx = n)."""
    keys = np.array([[0xFFFFFFFF, 5, 0xFFFFFFFF, 1, 5, 0xFFFFFFFF, 0, 2]],
                    np.uint32)
    idx = np.arange(8, dtype=np.int32).reshape(1, 8)
    sk, si = bitonic_sort_rows_stable(jnp.asarray(keys), jnp.asarray(idx),
                                      interpret=True)
    order = np.argsort(keys[0], kind="stable")
    assert np.array_equal(np.asarray(sk)[0], keys[0][order])
    assert np.array_equal(np.asarray(si)[0], order)    # full stability


def test_segmented_local_sort_done_flags(rng):
    """Only flagged segments are sorted; sentinel-colliding keys stay exact."""
    n = 1000
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    x[10] = x[600] = 0xFFFFFFFF                        # collide with pad value
    starts = jnp.asarray([0, 300, 640], jnp.int32)
    sizes = jnp.asarray([300, 340, 360], jnp.int32)
    flags = jnp.asarray([True, False, True])
    src, dst = segmented_local_sort(jnp.asarray(x), starts, sizes, flags, 512,
                                    interpret=True)
    s, d = np.asarray(src), np.asarray(dst)
    out = x.copy()
    m = d < n
    out[d[m]] = x[np.clip(s, 0, n - 1)[m]]
    want = x.copy()
    want[:300] = np.sort(x[:300])
    want[640:] = np.sort(x[640:])
    assert np.array_equal(out, want)
