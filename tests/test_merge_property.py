"""Property wall for the merge-path partition math, in isolation.

``kernels.merge``'s diagonal partition (``_coranks`` on device,
``host_coranks`` on host-spilled runs) and its strip/tile descriptors
(``merge_path_partition``, ``spill_group_plan``) were previously exercised
only end-to-end through ``oocsort``; this file pins their contracts
directly:

  * co-ranks are monotone non-decreasing along diagonals and sum to every
    diagonal exactly,
  * the selected prefix at every diagonal is exactly the m smallest window
    elements under (key, run, position) order,
  * partition strips tile every output position exactly once, and replaying
    the per-tile windows reproduces the stable-merge oracle — so the (key,
    run, position) tie order survives tile AND slab-strip boundaries,
  * degenerate runs: empty, length-1, all-equal, single-run.

The deterministic sweep runs everywhere; hypothesis (optional test
dependency, as in test_core_sort) widens the same properties and is marked
``slow`` (full-stage only).
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:  # hypothesis is an optional test dependency (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False

from repro.kernels import merge as kmerge
from repro.kernels.fused import pad_length

SENTINEL32 = np.uint32(0xFFFFFFFF)


# --------------------------- oracles ---------------------------------------

def _oracle_merge_order(runs):
    """Stable k-way merge order of ``runs`` under (key, run, position)."""
    if not runs or sum(len(r) for r in runs) == 0:
        return (np.empty(0, np.uint32), np.empty(0, np.int64),
                np.empty(0, np.int64))
    keys = np.concatenate(runs)
    rid = np.concatenate([np.full(len(r), i, np.int64)
                          for i, r in enumerate(runs)])
    pos = np.concatenate([np.arange(len(r), dtype=np.int64) for r in runs])
    order = np.lexsort((pos, rid, keys))
    return keys[order], rid[order], pos[order]


def _device_coranks(runs, diags):
    """Drive the device ``_coranks`` on sentinel-padded stacked rows."""
    lens = [len(r) for r in runs]
    lmax = max(max(lens), 1)
    rows = [np.concatenate([r, np.full(lmax - len(r), SENTINEL32,
                                       np.uint32)]) for r in runs]
    return np.asarray(kmerge._coranks(jnp.stack([jnp.asarray(r)
                                                 for r in rows]),
                                      tuple(lens), np.asarray(diags)))


def _check_coranks(runs, diags, cor):
    """The three co-rank contracts at every diagonal."""
    cor = np.asarray(cor, np.int64)
    lens = np.array([len(r) for r in runs], np.int64)
    diags = np.asarray(diags, np.int64)
    # bounds and per-diagonal sum
    assert (cor >= 0).all() and (cor <= lens[None, :]).all()
    assert np.array_equal(cor.sum(axis=1), diags)
    # monotone non-decreasing along diagonals, run by run
    assert (np.diff(cor, axis=0) >= 0).all()
    # the selected prefix is exactly the m smallest under (key, run, pos):
    # the oracle's first m elements, counted per run, ARE the co-ranks
    _, rid, _ = _oracle_merge_order(runs)
    for i, m in enumerate(diags):
        taken = np.bincount(rid[:m].astype(np.int64), minlength=len(runs))
        assert np.array_equal(cor[i], taken), (m, cor[i], taken)


def _replay_round(runs, kway, tile):
    """Replay one flat merge round from its partition tables, in numpy."""
    lens = tuple(len(r) for r in runs)
    n = sum(lens)
    n_pad = pad_length(n, tile)
    flat = np.concatenate([np.concatenate(runs),
                           np.full(n_pad - n, SENTINEL32, np.uint32)])
    tables = kmerge.merge_path_partition(jnp.asarray(flat), lens, kway, tile)
    out_off, out_cnt, ws, wt = (np.asarray(t) for t in tables)
    out = np.empty(n, np.uint32)
    seen = np.zeros(n, np.int64)
    for g in range(out_off.shape[0]):
        elems = []
        for r in range(kway):
            s, t = ws[g * kway + r], wt[g * kway + r]
            assert 0 <= s <= n and 0 <= t <= tile
            elems.append(flat[s:s + t])
        assert sum(len(e) for e in elems) == out_cnt[g]
        mk, _, _ = _oracle_merge_order(elems)        # tile-local stable merge
        out[out_off[g]:out_off[g] + out_cnt[g]] = mk
        seen[out_off[g]:out_off[g] + out_cnt[g]] += 1
    assert (seen == 1).all()                         # exactly-once tiling
    return out


def _replay_strips(runs, kway, tile, slab):
    """Replay a spill-planned group strip by strip, in numpy."""
    glen = sum(len(r) for r in runs)
    strips = kmerge.spill_group_plan(runs, kway, tile, slab)
    G = slab // tile
    out = np.empty(glen, np.uint32)
    seen = np.zeros(glen, np.int64)
    for s in strips:
        assert sum(s.win_len) == s.out_len
        off, cnt, ws, wt = s.tables
        assert off.shape == (G,) and ws.shape == (G * kway,)
        wins = [runs[r][s.win_lo[r]:s.win_lo[r] + s.win_len[r]]
                for r in range(len(runs))]
        slab_buf = np.concatenate(
            wins + [np.full(pad_length(slab, tile) - s.out_len, SENTINEL32,
                            np.uint32)])
        for g in range(G):
            elems = [slab_buf[ws[g * kway + r]:ws[g * kway + r] +
                              wt[g * kway + r]] for r in range(kway)]
            assert sum(len(e) for e in elems) == cnt[g]
            mk, _, _ = _oracle_merge_order(elems)
            lo = s.out_lo + off[g]
            out[lo:lo + cnt[g]] = mk
            seen[lo:lo + cnt[g]] += 1
    assert (seen == 1).all()                         # strips tile exactly once
    return out


def _assert_group_merges(runs, kway, tile, slab):
    """Both partition flavours replay to the stable-merge oracle."""
    ref, _, _ = _oracle_merge_order(runs)
    if len(runs) <= kway and sum(len(r) for r in runs):
        got = _replay_round(runs, kway, tile)
        assert np.array_equal(got, ref), "flat partition replay"
    got = _replay_strips(runs, kway, tile, slab)
    assert np.array_equal(got, ref), "strip replay"


# --------------------------- deterministic sweep ----------------------------

def _runs(rng, lens, hi=64):
    return [np.sort(rng.integers(0, hi, l).astype(np.uint32)) for l in lens]


CASES = [
    (77, 33, 10, 5),          # uneven four-way
    (64, 64, 64, 64),         # aligned
    (100, 1),                 # length-1 run
    (1, 1, 1, 1),             # all length-1
    (0, 50, 0, 3),            # empty runs interleaved
    (5,),                     # single run (copy-through partition)
    (256, 17, 96),
]


@pytest.mark.parametrize("lens", CASES, ids=[str(c) for c in CASES])
def test_partition_and_strips_match_oracle(rng, lens):
    runs = _runs(rng, lens)
    _assert_group_merges(runs, kway=4, tile=8, slab=16)
    _assert_group_merges(runs, kway=4, tile=16, slab=64)


def test_all_equal_keys_keep_run_order(rng):
    """Ties everywhere: the merged output must keep (run, position) order,
    across tile and strip boundaries alike."""
    runs = [np.full(l, 7, np.uint32) for l in (40, 13, 0, 25)]
    ref_k, ref_r, ref_p = _oracle_merge_order(runs)
    assert (np.diff(ref_r) >= 0).all()               # oracle sanity
    _assert_group_merges(runs, kway=4, tile=8, slab=16)


def test_sentinel_valued_keys(rng):
    """Keys equal to the pad sentinel must still merge exactly once."""
    runs = [np.sort(np.where(rng.random(l) < 0.5, SENTINEL32,
                             rng.integers(0, 9, l)).astype(np.uint32))
            for l in (30, 22, 9)]
    _assert_group_merges(runs, kway=4, tile=8, slab=16)


@pytest.mark.parametrize("lens", [(77, 33, 10, 5), (0, 50, 0, 3), (1, 200)])
def test_host_coranks_contracts_and_device_parity(rng, lens):
    runs = _runs(rng, lens, hi=32)
    glen = sum(lens)
    diags = np.minimum(np.arange(0, glen + 7, 7), glen)
    cor = kmerge.host_coranks(runs, diags)
    _check_coranks(runs, diags, cor)
    assert np.array_equal(cor, _device_coranks(runs, diags))


def test_host_coranks_degenerate():
    empty = [np.empty(0, np.uint32), np.empty(0, np.uint32)]
    cor = kmerge.host_coranks(empty, [0])
    assert np.array_equal(cor, [[0, 0]])
    ones = [np.array([3], np.uint32), np.array([3], np.uint32)]
    cor = kmerge.host_coranks(ones, [0, 1, 2])
    _check_coranks(ones, [0, 1, 2], cor)
    # equal keys split by run order: diagonal 1 must take from run 0
    assert np.array_equal(cor[1], [1, 0])


def test_spill_group_plan_validation():
    runs = [np.zeros(4, np.uint32)]
    with pytest.raises(ValueError):
        kmerge.spill_group_plan(runs, 4, 8, 12)      # not a tile multiple
    with pytest.raises(ValueError):
        kmerge.spill_group_plan(runs, 4, 8, 0)


def test_spill_strip_dead_slots_point_at_pad(rng):
    """Dead tiles / padded runs must aim at the slab pad (start = slab,
    take = 0) so the kernel's window loads stay in bounds."""
    runs = _runs(rng, (10, 5))                       # K=2 < kway=4, 1 strip
    (s,) = kmerge.spill_group_plan(runs, kway=4, tile=16, slab_elems=64)
    off, cnt, ws, wt = s.tables
    G = 4
    live_tiles = -(-15 // 16)
    assert (cnt[live_tiles:] == 0).all()
    dead = wt.reshape(G, 4) == 0
    assert (ws.reshape(G, 4)[dead] == 64).all()


# --------------------------- hypothesis drivers -----------------------------

if HAVE_HYPOTHESIS:
    run_lists = st.lists(
        st.lists(st.integers(0, 30), min_size=0, max_size=50),
        min_size=1, max_size=5)

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(data=run_lists, tile=st.sampled_from([8, 16]),
           kway=st.integers(2, 5), slab_tiles=st.integers(1, 4))
    def test_hypothesis_partition_oracle(data, tile, kway, slab_tiles):
        runs = [np.sort(np.asarray(d, np.uint32)) for d in data]
        _assert_group_merges(runs, kway=max(kway, len(runs)), tile=tile,
                             slab=slab_tiles * tile)

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(data=run_lists, step=st.integers(1, 9))
    def test_hypothesis_coranks(data, step):
        runs = [np.sort(np.asarray(d, np.uint32)) for d in data]
        glen = sum(len(r) for r in runs)
        diags = np.minimum(np.arange(0, glen + step, step), glen)
        cor = kmerge.host_coranks(runs, diags)
        _check_coranks(runs, diags, cor)
        if len(runs) > 1:
            assert np.array_equal(cor, _device_coranks(runs, diags))
