"""Out-of-core parity wall: ``oocsort`` ≡ the argsort reference, bytewise.

Correctness of the §5 pipeline spans launch boundaries — chunk sorts, the
double-buffered staging, and ⌈log_K⌉ merge-kernel rounds — so the fence is a
byte-identical comparison against the one-shot references across dtypes, KV
payloads, and every chunk-boundary shape, plus the structural gates: the
merge phase is comparison-sort-free and exactly ONE Pallas launch per round,
and the chunk-sort loop keeps the PR 2 one-launch-per-pass invariant.

Floats: the radix total order splits -0.0 < +0.0 (NaN payloads likewise), so
float keys are byte-compared against ``hybrid_sort``'s argsort engine (the
same total order) and semantically against ``np.sort``.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SortConfig, hybrid_sort
from repro.core.outofcore import (OocStats, _sort_chunk, merge_round, oocsort)
from repro.kernels import merge as kmerge
from repro.kernels.fused import pad_length
from repro.utils import hlo
from conftest import entropy_keys

CHUNK = 256
# small thresholds so kernel-engine chunk sorts exercise every phase
TCFG = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)


def _reference(x):
    """Byte-exact reference: the argsort-engine sort (radix total order)."""
    return np.asarray(hybrid_sort(jnp.asarray(x)))


def _keys(rng, dtype, n):
    if dtype == np.float32:
        x = (rng.standard_normal(n) * 1e3).astype(dtype)
        if n >= 8:
            x[:4] = [0.0, -0.0, np.inf, -np.inf]
        return x
    return entropy_keys(rng, n, 1, dtype=np.uint32).astype(dtype)


# ---------------- keys parity across dtypes and chunk boundaries ------------

@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32])
@pytest.mark.parametrize(
    "n", [0, 1, 100, CHUNK, CHUNK + 1,
          pytest.param(8 * CHUNK, marks=pytest.mark.slow),
          pytest.param(8 * CHUNK + 1, marks=pytest.mark.slow),
          pytest.param(9 * CHUNK - 1, marks=pytest.mark.slow)],
    ids=["empty", "one", "lt-chunk", "eq-chunk", "mod1", "mod0",
         "8mod1", "modKm1"])
def test_oocsort_keys_parity(rng, dtype, n):
    x = _keys(rng, dtype, n)
    out = oocsort(x, CHUNK, tile=32)
    assert isinstance(out, np.ndarray) and out.dtype == x.dtype
    assert np.array_equal(out, np.sort(x))
    assert out.tobytes() == _reference(x).tobytes()


@pytest.mark.slow
def test_oocsort_uint64(rng):
    from jax.experimental import enable_x64
    with enable_x64():
        x = entropy_keys(rng, 5 * CHUNK + 3, 2, dtype=np.uint64)
        out = oocsort(x, CHUNK, tile=32)
        assert np.array_equal(out, np.sort(x))
        assert out.tobytes() == _reference(x).tobytes()


@pytest.mark.slow
def test_oocsort_duplicates_and_sentinel(rng):
    for x in (np.zeros(1000, np.uint32),
              np.full(1000, 0xFFFFFFFF, np.uint32),      # == pad sentinel
              rng.integers(0, 8, 1500, dtype=np.uint32),
              np.where(rng.random(2000) < 0.3, 0xFFFFFFFF,
                       rng.integers(0, 2**32, 2000)).astype(np.uint32)):
        out = oocsort(x, 300, tile=32, kway=3)
        assert np.array_equal(out, np.sort(x))


# ---------------- KV parity (the acceptance criterion) ----------------------

def test_oocsort_kv_byte_identical_to_argsort_reference(rng):
    """n = 8x chunk_elems with unique keys: keys AND values byte-identical to
    the np.sort/np.argsort reference — the PR acceptance gate."""
    n = 8 * CHUNK
    x = rng.permutation(n).astype(np.uint32)            # unique keys
    v = rng.integers(0, 2**31, n).astype(np.int32)
    k, p = oocsort(x, CHUNK, values=np.arange(n, dtype=np.int32), tile=32)
    assert k.tobytes() == np.sort(x).tobytes()
    assert p.tobytes() == np.argsort(x, kind="stable").astype(
        np.int32).tobytes()
    k2, v2 = oocsort(x, CHUNK, values=v, tile=32)
    assert k2.tobytes() == np.sort(x).tobytes()
    assert v2.tobytes() == v[np.argsort(x, kind="stable")].tobytes()


@pytest.mark.parametrize("n", [0, 1, 200, CHUNK, 3 * CHUNK + 1])
def test_oocsort_kv_pair_consistency(rng, n):
    """Duplicate keys: pair movement is consistent (values travel with their
    keys) even where stability is not promised."""
    x = entropy_keys(rng, n, 3)
    v = np.arange(n, dtype=np.int32)
    k, p = oocsort(x, CHUNK, values=v, tile=32)
    assert np.array_equal(k, np.sort(x))
    assert np.array_equal(x[p], k)                      # pair consistency
    assert np.array_equal(np.sort(p), v)                # p is a permutation


def test_oocsort_value_pytree(rng):
    n = 3 * CHUNK
    x = rng.permutation(n).astype(np.uint32)
    vals = {"a": np.arange(n, dtype=np.int32),
            "b": np.arange(n, dtype=np.float32) * 2.0}
    k, out = oocsort(x, CHUNK, values=vals, tile=32)
    order = np.argsort(x, kind="stable")
    assert np.array_equal(k, np.sort(x))
    assert np.array_equal(out["a"], vals["a"][order])
    assert np.array_equal(out["b"], vals["b"][order])


# ---------------- streaming readers and chunk plans -------------------------

def test_oocsort_iterator_reader(rng):
    pieces = [rng.integers(0, 2**32, m, dtype=np.uint32)
              for m in (100, 700, 3, 0, 450)]
    full = np.concatenate(pieces)
    out = oocsort(iter(pieces), 256, engine="argsort", tile=32)
    assert np.array_equal(out, np.sort(full))


def test_oocsort_iterator_kv_tuples(rng):
    pieces, off = [], 0
    for m in (300, 300, 123):
        k = rng.integers(0, 2**32, m, dtype=np.uint32)
        pieces.append((k, np.arange(off, off + m, dtype=np.int32)))
        off += m
    full = np.concatenate([k for k, _ in pieces])
    k, p = oocsort(iter(pieces), 256, tile=32)
    assert np.array_equal(k, np.sort(full))
    assert np.array_equal(full[p], k)


def test_oocsort_stats_and_round_count(rng):
    x = rng.integers(0, 2**32, 8 * CHUNK, dtype=np.uint32)
    for kway, rounds in ((2, 3), (4, 2), (8, 1)):
        out, stats = oocsort(x, CHUNK, engine="argsort", kway=kway, tile=32,
                             return_stats=True)
        assert np.array_equal(out, np.sort(x))
        assert isinstance(stats, OocStats)
        assert stats.num_chunks == 8
        assert stats.merge_rounds == rounds == kmerge.num_merge_rounds(8, kway)
        assert stats.h2d_bytes == x.nbytes and stats.d2h_bytes == x.nbytes


def test_oocsort_engine_parity(rng):
    """Chunked kernel-engine == chunked argsort-engine, byte for byte."""
    x = entropy_keys(rng, 6 * CHUNK + 17, 2)
    a = oocsort(x, CHUNK, cfg=TCFG, engine="argsort", tile=32)
    k = oocsort(x, CHUNK, cfg=TCFG, engine="kernel", tile=32)
    assert a.tobytes() == k.tobytes()
    assert np.array_equal(a, np.sort(x))


@pytest.mark.slow
def test_oocsort_chunking_invariance(rng):
    """The output is independent of the chunk plan (unique keys: bytewise)."""
    n = 2048
    x = rng.permutation(n).astype(np.uint32)
    ref = oocsort(x, n, tile=32)                        # single run
    for chunk in (100, 256, 1000):
        assert oocsort(x, chunk, tile=32).tobytes() == ref.tobytes(), chunk


def test_oocsort_validation(rng):
    with pytest.raises(ValueError):
        oocsort(np.zeros(4, np.uint32), 0)
    with pytest.raises(ValueError):
        oocsort(np.zeros(4, np.uint32), 4, kway=1)
    with pytest.raises(ValueError):
        oocsort(np.zeros((2, 2), np.uint32), 4)
    with pytest.raises(ValueError):
        oocsort(iter([np.zeros(4, np.uint32)]), 4,
                values=np.zeros(4, np.int32))
    with pytest.raises(ValueError):
        oocsort(iter([]), 4)
    with pytest.raises(ValueError, match="key dtype"):   # silent-promotion trap
        oocsort(iter([np.zeros(4, np.uint32), np.zeros(4, np.int32)]), 4)
    with pytest.raises(ValueError, match="value dtypes"):
        oocsort(iter([(np.zeros(4, np.uint32), np.zeros(4, np.int32)),
                      (np.zeros(4, np.uint32), np.zeros(4, np.int64))]), 4)
    with pytest.raises(ValueError, match="1-D"):         # flat-slab contract
        oocsort(np.zeros(4, np.uint32), 2,
                values=np.ones((4, 3), np.float32))
    if not jax.config.jax_enable_x64:  # no silent payload truncation
        with pytest.raises(RuntimeError, match="64-bit value"):
            oocsort(np.zeros(4, np.uint32), 2,
                    values=np.arange(4, dtype=np.int64))


def test_oocsort_validation_names_offending_chunk():
    """Mismatch errors point at the chunk index that broke the contract —
    a multi-GB reader stream is undebuggable without it."""
    ok = np.zeros(4, np.uint32)
    with pytest.raises(ValueError, match=r"chunk 1.*key dtype"):
        oocsort(iter([ok, np.zeros(4, np.int32)]), 4)
    with pytest.raises(ValueError, match=r"chunk 2.*value structure"):
        oocsort(iter([(ok, ok), (ok, ok),
                      (ok, (ok, ok))]), 4)
    with pytest.raises(ValueError, match=r"chunk 1.*value dtypes"):
        oocsort(iter([(ok, np.zeros(4, np.int32)),
                      (ok, np.zeros(4, np.int64))]), 4)
    with pytest.raises(ValueError, match=r"chunk 0.*1-D"):
        oocsort(iter([np.zeros((2, 2), np.uint32)]), 4)
    with pytest.raises(ValueError, match=r"chunk 1.*match the key length"):
        oocsort(iter([(ok, ok), (ok, np.zeros(3, np.uint32))]), 4)


def test_length_bucketing_ooc_route(rng):
    """data.pipeline routes shard-sized corpora through oocsort: same packing
    contract as the LSD path."""
    from repro.data import length_bucketed_batches
    lengths = rng.integers(1, 512, 600)
    order, bounds = length_bucketed_batches(lengths, batch_tokens=4096,
                                            ooc_chunk_elems=128)
    ref_order, ref_bounds = length_bucketed_batches(lengths,
                                                    batch_tokens=4096)
    assert sorted(order.tolist()) == list(range(600))
    sl = lengths[order]
    assert (np.diff(sl) >= 0).all()
    assert bounds == ref_bounds
    assert np.array_equal(sl, lengths[ref_order])
    for a, b in zip(bounds[:-1], bounds[1:]):
        assert sl[a:b].max() * (b - a) <= 4096


# ---------------- host-spill streaming merge (§5 beyond-device-memory) ------

SPILL_TILE = 16
SPILL_BUDGET = 4096      # device-byte budget; parity gates feed 16x its bytes


def test_oocsort_spill_16x_budget_keys(rng):
    """THE spill acceptance gate: an input 16x the device budget sorts
    byte-identically while the driver's device high-water mark stays under
    the budget — the test that fails if anyone re-materialises full runs on
    device."""
    n = 16 * SPILL_BUDGET // 4
    x = _keys(rng, np.uint32, n)
    out, st = oocsort(x, 1 << 20, engine="argsort", tile=SPILL_TILE,
                      spill_budget_bytes=SPILL_BUDGET, return_stats=True)
    assert x.nbytes >= 16 * SPILL_BUDGET
    assert out.tobytes() == _reference(x).tobytes()
    assert st.spill_slab_elems > 0
    assert st.rounds_spilled == st.merge_rounds > 0
    assert st.device_high_water_bytes <= SPILL_BUDGET
    assert st.device_high_water_bytes < x.nbytes // 8   # runs stayed host-side


def test_oocsort_spill_16x_budget_kv(rng):
    """Spill acceptance, KV flavour: keys AND values byte-identical to the
    np.sort/np.argsort reference under a 16x-budget (key+value bytes) load."""
    n = 16 * SPILL_BUDGET // 8                          # 8 B per (key, value)
    x = rng.permutation(n).astype(np.uint32)            # unique keys
    v = np.arange(n, dtype=np.int32)
    assert x.nbytes + v.nbytes >= 16 * SPILL_BUDGET
    k, p, st = oocsort(x, 1 << 20, values=v, engine="argsort",
                       tile=SPILL_TILE, spill_budget_bytes=SPILL_BUDGET,
                       return_stats=True)
    assert k.tobytes() == np.sort(x).tobytes()
    assert p.tobytes() == np.argsort(x, kind="stable").astype(
        np.int32).tobytes()
    assert st.device_high_water_bytes <= SPILL_BUDGET


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32])
def test_oocsort_spill_16x_budget_dtypes(rng, dtype):
    """Full-stage dtype sweep of the 16x-budget spill parity gate."""
    n = 16 * SPILL_BUDGET // 4
    x = _keys(rng, dtype, n)
    out, st = oocsort(x, 1 << 20, engine="argsort", tile=SPILL_TILE,
                      spill_budget_bytes=SPILL_BUDGET, return_stats=True)
    assert out.tobytes() == _reference(x).tobytes()
    assert np.array_equal(out, np.sort(x))
    assert st.device_high_water_bytes <= SPILL_BUDGET


@pytest.mark.slow
def test_oocsort_spill_uint64(rng):
    from jax.experimental import enable_x64
    with enable_x64():
        n = 16 * SPILL_BUDGET // 8
        x = entropy_keys(rng, n, 2, dtype=np.uint64)
        out, st = oocsort(x, 1 << 20, engine="argsort", tile=SPILL_TILE,
                          spill_budget_bytes=SPILL_BUDGET, return_stats=True)
        assert out.tobytes() == _reference(x).tobytes()
        assert st.device_high_water_bytes <= SPILL_BUDGET


def test_oocsort_spill_equals_device_resident(rng):
    """Regime invariance: the streamed merge is byte-identical to the
    device-resident merge (same merge path, same tie order) for any slab."""
    x = entropy_keys(rng, 3000, 3)                      # heavy duplicates
    flat = oocsort(x, 300, engine="argsort", tile=32)
    for slab in (64, 128, 960):
        sp = oocsort(x, 300, engine="argsort", tile=32,
                     device_slab_elems=slab)
        assert sp.tobytes() == flat.tobytes(), slab


def test_oocsort_spill_value_pytree(rng):
    n = 6 * 128
    x = rng.permutation(n).astype(np.uint32)
    vals = {"a": np.arange(n, dtype=np.int32),
            "b": np.arange(n, dtype=np.float32) * 2.0}
    k, out = oocsort(x, 128, values=vals, tile=SPILL_TILE,
                     device_slab_elems=64)
    order = np.argsort(x, kind="stable")
    assert np.array_equal(k, np.sort(x))
    assert np.array_equal(out["a"], vals["a"][order])
    assert np.array_equal(out["b"], vals["b"][order])


@pytest.mark.slow
def test_oocsort_spill_engine_parity(rng):
    """Spilled kernel-engine chunk sorts == spilled argsort-engine, bytewise."""
    x = entropy_keys(rng, 4 * CHUNK, 2)
    a = oocsort(x, CHUNK, cfg=TCFG, engine="argsort", tile=32,
                device_slab_elems=128)
    k = oocsort(x, CHUNK, cfg=TCFG, engine="kernel", tile=32,
                device_slab_elems=128)
    assert a.tobytes() == k.tobytes()
    assert np.array_equal(a, np.sort(x))


def test_oocsort_spill_link_byte_formula(rng):
    """Host-byte accounting matches the §5 formula exactly: the chunk phase
    crosses 2·N·b and every spilled round adds 2·N·b (16 = 4² runs: no
    leftover groups anywhere)."""
    n = 16 * 64
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    out, st = oocsort(x, 64, engine="argsort", kway=4, tile=8,
                      device_slab_elems=32, return_stats=True)
    assert np.array_equal(out, np.sort(x))
    nb = x.nbytes
    assert st.num_chunks == 16 and st.rounds_spilled == 2
    assert st.chunk_link_bytes == 2 * nb
    assert st.spill_link_bytes == 2 * nb * st.rounds_spilled
    assert st.h2d_bytes == st.d2h_bytes == nb * (1 + st.rounds_spilled)
    assert st.h2d_bytes + st.d2h_bytes == \
        st.chunk_link_bytes + st.spill_link_bytes

    v = np.arange(n, dtype=np.int32)                    # payload doubles b
    k, p, st = oocsort(x, 64, values=v, engine="argsort", kway=4, tile=8,
                       device_slab_elems=32, return_stats=True)
    assert st.chunk_link_bytes == 2 * (nb + v.nbytes)
    assert st.spill_link_bytes == 2 * (nb + v.nbytes) * st.rounds_spilled


def test_oocsort_spill_leftover_runs_skip_crossings(rng):
    """Single-run leftover groups carry over host-side for free: their
    round's crossings exclude them, exactly."""
    n = 5 * 64                                          # 5 runs, kway=4
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    out, st = oocsort(x, 64, engine="argsort", kway=4, tile=8,
                      device_slab_elems=32, return_stats=True)
    assert np.array_equal(out, np.sort(x))
    assert st.num_chunks == 5 and st.rounds_spilled == 2
    # round 1: only the 4-run group (256 keys) streams; round 2: both runs
    assert st.spill_link_bytes == 2 * (256 * 4) + 2 * (320 * 4)


def test_oocsort_spill_stats_defaults(rng):
    """Device-resident sorts report zeroed spill fields and a high-water
    mark that scales with the whole input (the footprint spill removes)."""
    x = rng.integers(0, 2**32, 8 * CHUNK, dtype=np.uint32)
    out, st = oocsort(x, CHUNK, tile=32, return_stats=True)
    assert st.rounds_spilled == 0 and st.spill_slab_elems == 0
    assert st.spill_link_bytes == 0
    assert st.chunk_link_bytes == 2 * x.nbytes
    assert st.device_high_water_bytes > x.nbytes        # flat ping-pong pair


def test_oocsort_spill_validation():
    x = np.zeros(64, np.uint32)
    with pytest.raises(ValueError, match="spill_budget_bytes"):
        oocsort(x, 16, spill_budget_bytes=0)
    with pytest.raises(ValueError, match="too small"):
        oocsort(x, 16, tile=32, spill_budget_bytes=100)
    with pytest.raises(ValueError, match="device_slab_elems"):
        oocsort(x, 16, tile=32, device_slab_elems=8)


def test_oocsort_spill_validation_is_input_independent():
    """A misconfigured slab/budget must fail on empty inputs too, not only
    on the first non-empty batch of a pipeline."""
    empty = np.empty(0, np.uint32)
    with pytest.raises(ValueError, match="device_slab_elems"):
        oocsort(empty, 16, tile=32, device_slab_elems=8)
    with pytest.raises(ValueError, match="too small"):
        oocsort(empty, 16, tile=32, spill_budget_bytes=100)
    out = oocsort(empty, 16, tile=32, device_slab_elems=64)   # valid: fine
    assert out.shape == (0,)


def test_oocsort_spill_budget_is_hard_even_when_tight(rng):
    """The budget is a HARD ceiling at every accepted size: tight budgets
    where the pad tile and descriptor tables rival the slab payload must
    either shrink the slab to fit or refuse — never silently overshoot."""
    x = rng.integers(0, 2**32, 2000, dtype=np.uint32)
    with pytest.raises(ValueError, match="too small"):     # < one-tile peak
        oocsort(x, 1 << 20, engine="argsort", tile=8, spill_budget_bytes=300)
    for budget in (650, 2000):
        out, st = oocsort(x, 1 << 20, engine="argsort", tile=8,
                          spill_budget_bytes=budget, return_stats=True)
        assert np.array_equal(out, np.sort(x))
        assert st.device_high_water_bytes <= budget, budget


def test_oocsort_spill_budget_models_kernel_engine_padding(rng):
    """Kernel-engine chunk sorts allocate pad_length(n, kpb)-sized ping-pong
    pairs; a budget far below that must refuse rather than let real device
    allocations overshoot while the ledger reports compliance."""
    x = rng.integers(0, 2**32, 512, dtype=np.uint32)
    with pytest.raises(ValueError, match="chunk phase"):
        # default cfg: kpb=3456 -> ~110 KB modeled for even a 1-elem chunk
        oocsort(x, 256, engine="kernel", tile=16, spill_budget_bytes=4096)
    # a small-kpb cfg fits the same budget, honestly accounted
    out, st = oocsort(x, 256, cfg=TCFG, engine="kernel", tile=16,
                      spill_budget_bytes=8192, return_stats=True)
    assert np.array_equal(out, np.sort(x))
    assert st.device_high_water_bytes <= 8192


def test_oocsort_spill_explicit_slab_with_roomy_budget(rng):
    """A valid explicit slab must not be rejected just because a (large)
    budget is also given: only the budget-DERIVED slab needs the 2-tile
    footprint headroom."""
    x = rng.integers(0, 2**32, 400, dtype=np.uint32)
    out, st = oocsort(x, 100, engine="argsort", tile=32,
                      device_slab_elems=32, spill_budget_bytes=1 << 30,
                      return_stats=True)
    assert np.array_equal(out, np.sort(x))
    assert st.spill_slab_elems == 32
    assert st.device_high_water_bytes <= 1 << 30
    # ... and under a TIGHT budget the explicit slab's own modeled peak is
    # what decides, not the derived-slab reservation
    out, st = oocsort(x, 100, engine="argsort", tile=32,
                      device_slab_elems=32, spill_budget_bytes=2000,
                      return_stats=True)
    assert np.array_equal(out, np.sort(x))
    assert st.spill_slab_elems == 32
    assert st.device_high_water_bytes <= 2000


def test_length_bucketing_spill_route(rng):
    """The spill options thread through data.pipeline: same packing contract
    as the device-resident ooc route."""
    from repro.data import length_bucketed_batches
    lengths = rng.integers(1, 512, 600)
    order, bounds = length_bucketed_batches(
        lengths, batch_tokens=4096, ooc_chunk_elems=128,
        ooc_spill_budget_bytes=64 * 1024)
    ref_order, ref_bounds = length_bucketed_batches(lengths,
                                                    batch_tokens=4096)
    assert sorted(order.tolist()) == list(range(600))
    assert bounds == ref_bounds
    assert np.array_equal(lengths[order], lengths[ref_order])
    # spill options without the ooc route are a misconfiguration, not a no-op
    with pytest.raises(ValueError, match="ooc_chunk_elems"):
        length_bucketed_batches(lengths, batch_tokens=4096,
                                ooc_spill_budget_bytes=64 * 1024)


# ---------------- structural gates (acceptance criteria) --------------------

def _merge_round_jaxpr(lens, kway, tile, num_vals=0):
    n = sum(lens)
    n_pad = pad_length(n, tile)
    ck = jnp.zeros((n_pad,), jnp.uint32)
    cv = tuple(jnp.zeros((n_pad,), jnp.int32) for _ in range(num_vals))
    f = lambda a, b: merge_round(a, cv, b, tuple(jnp.zeros_like(v)
                                                 for v in cv),
                                 lens=tuple(lens), kway=kway, tile=tile, n=n,
                                 interpret=True)
    return f, ck, jnp.zeros_like(ck)


def test_merge_phase_is_comparison_sort_free():
    """utils.hlo.sort_op_count == 0 over the whole merge phase: the diagonal
    partition is binary search, the tile merge a counting rank."""
    for lens in ((256, 256, 256, 256), (256, 100), (300, 300, 300, 300, 17)):
        f, ck, ak = _merge_round_jaxpr(lens, kway=4, tile=64)
        assert hlo.sort_op_count(jax.jit(f).lower(ck, ak).as_text()) == 0, lens


def test_merge_round_single_launch_with_values():
    f, ck, ak = _merge_round_jaxpr((256, 256, 256), kway=4, tile=64,
                                   num_vals=2)
    jx = jax.make_jaxpr(f)(ck, ak)
    assert hlo.pallas_launch_count(jx) == 1
    assert hlo.launch_census(jx)["total"] == 1


# ---------------------------------------------------------------------------
# compressed-key mode (entropy-adaptive): pack to the live-bit carrier BEFORE
# any key crosses the link, so every link/slab/budget row shrinks with b_eff
# ---------------------------------------------------------------------------


def test_oocsort_compress_spill_clustered_smoke():
    """Clustered keys + ``compress=True`` through the host-spill regime.

    Live bits {0..5, 12..13} pack 4-byte keys into a uint8 carrier, so every
    link crossing (chunk staging, both spill rounds) pays 1 byte/key instead
    of 4 — and the chunk sorts run the 1-pass packed schedule instead of the
    2-pass narrowed (4-pass nominal) uint32 one.  The skewed cluster (56 of
    every 64 keys share the top digit) keeps a >local_threshold bucket alive
    after pass 0 of the UNcompressed sort, so the executed-pass reduction is
    strict, and the packed max value 0xFF doubles as a sentinel-collision
    probe.
    """
    rng = np.random.default_rng(11)
    n = 16 * 64
    c = np.where(np.arange(n) % 8 != 0, 0,
                 rng.integers(1, 4, n)).astype(np.uint32)
    x = (c << np.uint32(12)) | rng.integers(0, 64, n).astype(np.uint32)
    kw = dict(engine="argsort", cfg=TCFG, kway=4, tile=8,
              device_slab_elems=32, return_stats=True)
    plain, st_p = oocsort(x, 64, **kw)
    out, st = oocsort(x, 64, compress=True, **kw)

    assert out.dtype == np.uint32
    assert out.tobytes() == plain.tobytes() == np.sort(x).tobytes()

    # link formulas stay exact on the PACKED carrier byte size (b_eff = 1)
    for s, b in ((st_p, 4), (st, 1)):
        assert s.rounds_spilled == 2
        assert s.chunk_link_bytes == 2 * n * b
        assert s.spill_link_bytes == 2 * n * b * s.rounds_spilled
        assert s.retry_link_bytes == 0
        assert s.h2d_bytes + s.d2h_bytes == \
            s.chunk_link_bytes + s.spill_link_bytes + s.retry_link_bytes

    # chunk sorts report the reduced executed-pass totals: packed 8-bit keys
    # need 1 pass/chunk, the uncompressed narrowed window 2, nominal ⌈32/8⌉=4
    assert st.num_chunks == st_p.num_chunks == 16
    assert st.chunk_passes_executed == st.num_chunks
    assert st.chunk_passes_executed < st_p.chunk_passes_executed
    assert st_p.chunk_passes_executed < st_p.num_chunks * 4


def test_oocsort_compress_uint64_without_x64():
    """≤32 live bits let uint64 keys sort WITHOUT jax_enable_x64: the host
    packs to a uint32 carrier before the device ever sees a key (plain
    uint64 still refuses), and the link rows price the 4-byte carrier."""
    if jax.config.jax_enable_x64:
        pytest.skip("guard only meaningful with x64 disabled")
    rng = np.random.default_rng(7)
    n = 512
    x = ((rng.integers(0, 1 << 20, n).astype(np.uint64) << np.uint64(8))
         | np.uint64(0xA5 << 40))
    with pytest.raises(RuntimeError, match="x64"):
        oocsort(x, 128, engine="argsort")
    out, st = oocsort(x, 128, engine="argsort", cfg=TCFG, return_stats=True,
                      compress=True)
    assert out.dtype == np.uint64
    assert out.tobytes() == np.sort(x).tobytes()
    assert st.chunk_link_bytes == 2 * n * 4   # 20 live bits -> uint32 carrier
    # executed passes stay under the PACKED nominal ⌈20/8⌉ = 3 per chunk —
    # far below the ⌈64/8⌉ = 8 the uncompressed uint64 schedule would run
    assert 0 < st.chunk_passes_executed <= st.num_chunks * 3
