"""Substrate tests: optimizers, data determinism, checkpoint integrity,
train-loop resume (simulated failure), serving engine."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.optim import (adamw, adafactor, adamw8bit, clip_by_global_norm,
                         cosine_schedule, int8_compress, int8_decompress)
from repro.data import SyntheticLMData, length_bucketed_batches
from repro.checkpoint import (save_checkpoint, restore_checkpoint, latest_step,
                              AsyncCheckpointer)
from repro.train import Trainer, TrainState, make_train_step

KEY = jax.random.PRNGKey(0)


# ------------------------------ optimizers ----------------------------------

def _toy():
    params = {"w": jnp.ones((8, 16)), "b": jnp.zeros((16,))}
    grads = {"w": jnp.full((8, 16), 0.5), "b": jnp.full((16,), -0.25)}
    return params, grads


@pytest.mark.parametrize("maker", [adamw, adafactor, adamw8bit])
def test_optimizers_descend(maker):
    params, grads = _toy()
    opt = maker()
    state = opt.init(params)
    p1, state = opt.update(grads, state, params, 1e-2)
    # step moves opposite the gradient
    assert float(jnp.mean(p1["w"])) < float(jnp.mean(params["w"]))
    assert float(jnp.mean(p1["b"])) > float(jnp.mean(params["b"]))
    p2, state = opt.update(grads, state, p1, 1e-2)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(p2))


def test_adam8bit_tracks_adamw():
    params, grads = _toy()
    oa, ob = adamw(weight_decay=0.0), adamw8bit(weight_decay=0.0)
    sa, sb = oa.init(params), ob.init(params)
    pa, pb = params, params
    for _ in range(5):
        pa, sa = oa.update(grads, sa, pa, 1e-2)
        pb, sb = ob.update(grads, sb, pb, 1e-2)
    err = max(float(jnp.max(jnp.abs(x - y)))
              for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
    assert err < 5e-3, err


def test_adafactor_state_is_small():
    params = {"w": jnp.ones((256, 512))}
    st = adafactor().init(params)
    state_elems = sum(x.size for x in jax.tree.leaves(st["s"]))
    assert state_elems <= 256 + 512          # factored, not dense


def test_clip_and_schedule():
    _, grads = _toy()
    clipped, gn = clip_by_global_norm(grads, 1e-3)
    cn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(clipped)))
    assert float(cn) <= 1.1e-3
    lr = cosine_schedule(1e-3, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < 1e-5


def test_int8_compression_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal((1000,)).astype(np.float32))
    q, s = int8_compress(x)
    back = int8_decompress(q, s, x.shape)
    assert float(jnp.max(jnp.abs(back - x))) < float(jnp.max(jnp.abs(x))) / 100


# ------------------------------ data ----------------------------------------

def test_data_restart_exact():
    d = SyntheticLMData(vocab=100, seq_len=16, global_batch=4, seed=3)
    b1 = d.batch(7)
    b2 = d.batch(7)                      # a "restarted" pipeline
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(d.batch(8)["tokens"]),
                              np.asarray(b1["tokens"]))


def test_length_bucketing_packs(rng):
    lengths = rng.integers(1, 512, 200)
    order, bounds = length_bucketed_batches(lengths, batch_tokens=4096)
    assert sorted(order.tolist()) == list(range(200))
    sl = lengths[order]
    assert (np.diff(sl) >= 0).all()              # sorted by length
    for a, b in zip(bounds[:-1], bounds[1:]):
        assert sl[a:b].max() * (b - a) <= 4096   # every bucket fits


def test_length_bucketing_dist_mesh_route(rng):
    """dist_mesh= routes the ordering through the §5 shard exchange (a
    1-device mesh in-process: multi-device widths are covered by the
    subprocess walls) and must reproduce the host route's packing exactly,
    including a doc count that needs sentinel padding."""
    lengths = rng.integers(1, 512, 203)          # not a multiple of nshards
    mesh = jax.make_mesh((1,), ("data",))
    order, bounds = length_bucketed_batches(lengths, batch_tokens=4096,
                                            dist_mesh=mesh)
    ref_order, ref_bounds = length_bucketed_batches(lengths,
                                                    batch_tokens=4096)
    assert sorted(order.tolist()) == list(range(203))
    assert np.array_equal(lengths[order], lengths[ref_order])
    assert bounds == ref_bounds
    with pytest.raises(ValueError, match="exclusive"):
        length_bucketed_batches(lengths, batch_tokens=4096, dist_mesh=mesh,
                                ooc_chunk_elems=64)


# ------------------------------ checkpoint ----------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    like = jax.tree.map(jnp.zeros_like, tree)
    back = restore_checkpoint(str(tmp_path), 5, like)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.arange(100, dtype=jnp.float32)}
    path = save_checkpoint(str(tmp_path), 1, tree)
    chunk = os.path.join(path, "chunk_000000.zst")
    with open(chunk, "r+b") as f:
        f.seek(4)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), 1, tree)


def test_checkpoint_prunes_old(tmp_path):
    tree = {"a": jnp.zeros(4)}
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = [latest_step(str(tmp_path))]
    assert steps == [5]
    assert not os.path.exists(os.path.join(str(tmp_path), "step_0000000001"))


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    tree = {"a": jnp.arange(16.0)}
    ck.save(3, tree)
    ck.wait()
    assert latest_step(str(tmp_path)) == 3


# ------------------------------ train loop (failure + resume) ---------------

def test_train_resume_after_failure(tmp_path):
    cfg = get_smoke_config("internlm2_1_8b")
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=16, global_batch=2)
    tr = Trainer(cfg, data, str(tmp_path), ckpt_every=5, log_every=100,
                 total_steps=50)
    state = tr.init_or_resume(KEY)
    state = tr.run(state, 7)                 # "crash" after step 7 (ckpt at 5)
    losses_a = float(state.step)
    assert losses_a == 7

    tr2 = Trainer(cfg, data, str(tmp_path), ckpt_every=5, log_every=100,
                  total_steps=50)
    state2 = tr2.init_or_resume(KEY)
    assert int(state2.step) == 5             # resumed from the checkpoint
    state2 = tr2.run(state2, 5)
    assert int(state2.step) == 10

    # determinism: a run without failure reaches the same params at step 10
    tr3 = Trainer(cfg, data, str(tmp_path) + "_b", ckpt_every=100,
                  log_every=100, total_steps=50)
    state3 = tr3.init_or_resume(KEY)
    state3 = tr3.run(state3, 10)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(state2.params),
                              jax.tree.leaves(state3.params)))
    assert err < 1e-5, err


def test_loss_decreases_on_tiny_model(tmp_path):
    cfg = get_smoke_config("internlm2_1_8b")
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=4)
    tr = Trainer(cfg, data, str(tmp_path), ckpt_every=1000, log_every=1000,
                 base_lr=3e-3, total_steps=60)
    state = tr.init_or_resume(KEY)
    losses = []
    state = tr.run(state, 40, on_step=lambda s, st, m: losses.append(float(m["loss"])))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


# ------------------------------ serving -------------------------------------

def test_serve_engine_generates():
    from repro.serve import ServeEngine, Request
    cfg = get_smoke_config("internlm2_1_8b")
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    queue = [Request(i, rng.integers(0, cfg.vocab, rng.integers(3, 10)),
                     max_new_tokens=int(rng.integers(4, 12)))
             for i in range(5)]
    batches = eng.schedule(queue)
    assert sum(len(b) for b in batches) == 5
    done = eng.generate(batches[0])
    for r in done:
        assert r.generated is not None and len(r.generated) == r.max_new_tokens
        assert (r.generated >= 0).all() and (r.generated < cfg.vocab).all()


def test_microbatched_grads_match_full():
    """m-microbatch accumulation == full-batch gradient (mean loss)."""
    from repro.train import make_train_step, TrainState
    cfg = get_smoke_config("internlm2_1_8b")
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=16, global_batch=4)
    from repro.models import init_params
    params = init_params(cfg, KEY)
    batch = data.batch(0)

    opt1, step1 = make_train_step(cfg, donate=False)
    opt4, step4 = make_train_step(cfg, donate=False, microbatches=4)
    s1 = TrainState(params, opt1.init(params), jnp.int32(0))
    s4 = TrainState(params, opt4.init(params), jnp.int32(0))
    o1, m1 = step1(s1, batch)
    o4, m4 = step4(s4, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(o1.params),
                              jax.tree.leaves(o4.params)))
    assert err < 1e-5, err
