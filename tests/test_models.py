"""Model-zoo behaviour tests: decode/forward consistency, MoE dispatch
equivalence (sort == dense), SSM chunking invariance, window masking."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params, forward, decode_step, init_cache
from repro.models.moe import moe_layer, init_moe
from repro.models.ssm import ssm_forward, init_ssm

KEY = jax.random.PRNGKey(1)


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "mamba2_1_3b",
                                  "hymba_1_5b", "qwen3_moe_30b_a3b"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.is_moe:   # no-drop capacity so both paths keep all tokens
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = init_params(cfg, KEY)
    b, s = 2, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    logits_full, _ = forward(params, cfg, {"tokens": tokens})
    cache = init_cache(cfg, b, 32)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    outs = []
    for t in range(s):
        lg, cache = step(params, tokens[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - logits_full)))
    assert err < 2e-3, (arch, err)


def test_moe_sort_equals_dense_dispatch():
    cfg = get_smoke_config("qwen3_moe_30b_a3b")
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    l1, _ = forward(params, cfg, {"tokens": tokens})
    l2, _ = forward(params, dataclasses.replace(cfg, moe_dispatch="dense"),
                    {"tokens": tokens})
    assert float(jnp.max(jnp.abs(l1 - l2))) < 2e-4


def test_moe_grouped_dispatch_invariance():
    # with no drops, dispatch groups must not change the math
    cfg = dataclasses.replace(get_smoke_config("qwen3_moe_30b_a3b"),
                              capacity_factor=16.0)
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    l1, _ = forward(params, cfg, {"tokens": tokens})
    l2, _ = forward(params, dataclasses.replace(cfg, dispatch_groups=4),
                    {"tokens": tokens})
    assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-4


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(get_smoke_config("qwen3_moe_30b_a3b"),
                              capacity_factor=0.1)
    params = init_params(cfg, KEY)
    moe_p = jax.tree.map(lambda a: a[0], params["layers"]["moe"])  # layer 0
    x = jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.float32)
    out, aux = moe_layer(moe_p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    # with such a tiny capacity some tokens MUST differ from the no-drop run
    cfg2 = dataclasses.replace(cfg, capacity_factor=16.0)
    out2, _ = moe_layer(moe_p, x, cfg2)
    assert float(jnp.max(jnp.abs(out - out2))) > 1e-7


def test_ssm_chunk_invariance():
    cfg = get_smoke_config("mamba2_1_3b")
    params = init_params(cfg, KEY)["layers"]["ssm"]
    p0 = jax.tree.map(lambda a: a[0], params)     # layer 0
    x = jax.random.normal(KEY, (2, 64, cfg.d_model), jnp.float32)
    y1 = ssm_forward(p0, x, cfg)                                  # chunk 16
    y2 = ssm_forward(p0, x, dataclasses.replace(cfg, ssm_chunk=64))
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-3


def test_hymba_window_masks_differ():
    cfg = get_smoke_config("hymba_1_5b")          # window 8, layer 0 global
    params = init_params(cfg, KEY)
    b, s = 1, 24
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    l1, _ = forward(params, cfg, {"tokens": tokens})
    cfg_full = dataclasses.replace(cfg, attn_window=0)
    l2, _ = forward(params, cfg_full, {"tokens": tokens})
    # early positions identical (window not yet binding), late differ
    assert float(jnp.max(jnp.abs(l1[:, :4] - l2[:, :4]))) < 1e-4
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) > 1e-6


def test_vlm_frontend_changes_output():
    cfg = get_smoke_config("internvl2_26b")
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    p1 = jax.random.normal(KEY, (2, cfg.num_patches, cfg.d_model))
    logits, _ = forward(params, cfg, {"tokens": tokens, "patches": p1})
    assert logits.shape[1] == cfg.num_patches + 8
    logits2, _ = forward(params, cfg, {"tokens": tokens, "patches": p1 * 2})
    assert float(jnp.max(jnp.abs(logits - logits2))) > 1e-6


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "mamba2_1_3b",
                                  "hymba_1_5b", "qwen3_moe_30b_a3b"])
def test_prefill_then_decode_matches_forward(arch):
    from repro.models import prefill
    cfg = get_smoke_config(arch)
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    if cfg.has_ssm:
        cfg = dataclasses.replace(cfg, ssm_chunk=8)
    params = init_params(cfg, KEY)
    b, s = 2, 16
    tokens = jax.random.randint(KEY, (b, s + 4), 0, cfg.vocab)
    lg, cache = prefill(params, cfg, {"tokens": tokens[:, :s]}, max_len=s + 8)
    full, _ = forward(params, cfg, {"tokens": tokens})
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, s - 1])))]
    for t in range(4):
        lg, cache = decode_step(params, cfg, tokens[:, s + t:s + t + 1], cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, s + t]))))
    assert max(errs) < 2e-3, (arch, errs)


@pytest.mark.parametrize("s,block,window", [(17, 4, None), (32, 8, None),
                                            (40, 16, 8), (64, 64, None)])
def test_flash_attention_matches_naive(s, block, window):
    """Property sweep: blockwise flash == materialised softmax attention,
    including ragged tails and sliding windows."""
    import jax.numpy as jnp
    from repro.models.layers import flash_attention, _gqa_scores, _gqa_values
    key = jax.random.PRNGKey(0)
    b, h, hd = 2, 3, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    w = None if window is None else jnp.int32(window)

    out_f = flash_attention(q, k, v, positions, w, block)
    scores = _gqa_scores(q, k, 1) / jnp.sqrt(jnp.float32(hd))
    ii, jj = positions[:, None, :, None], positions[:, None, None, :]
    mask = jj <= ii
    if w is not None:
        mask &= (w == 0) | (jj > ii - w)
    probs = jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1)
    out_n = _gqa_values(probs.astype(v.dtype), v, 1)
    assert float(jnp.max(jnp.abs(out_f - out_n))) < 1e-5


def test_abstract_mesh_shim_resolves_mesh_without_modern_api(monkeypatch):
    """The jax-version shim for ``jax.sharding.get_abstract_mesh``: with the
    modern accessor monkeypatched away, ``layers.abstract_mesh`` must still
    resolve the mesh bound by the ``with mesh:`` context (thread_resources
    fallback), and the layer helpers must degrade to their off-mesh no-ops
    when nothing is bound — the failure mode that broke every qwen3_moe /
    kimi_k2 smoke, decode-parity and MoE dispatch test on older jax."""
    from repro.models import layers
    from jax.sharding import PartitionSpec as P

    monkeypatch.delattr(jax.sharding, "get_abstract_mesh", raising=False)

    # off-mesh: no mesh resolved, dp_axes empty, constrain is the identity
    assert layers.abstract_mesh() is None
    assert layers.dp_axes() == ()
    x = jnp.zeros((4, 4))
    assert layers.constrain(x, P("data", None)) is x

    # bound mesh: resolved through the thread_resources fallback
    devs = np.array(jax.devices()[:1])
    with jax.sharding.Mesh(devs, ("data",)):
        am = layers.abstract_mesh()
        assert am is not None and "data" in am.axis_names
        assert layers.dp_axes() == ("data",)
        # constraint over a bound axis binds; over an unbound one no-ops
        y = layers.constrain(x, P("data", None))
        assert y.shape == x.shape
        assert layers.constrain(x, P("model", None)) is x
    assert layers.dp_axes() == ()               # context exit unbinds
