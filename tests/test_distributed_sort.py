"""Distributed sort (§5 analogue): multi-device tests run in a subprocess
(tests/_multidev.py) so the fake-device XLA flag never leaks into the rest
of the suite; the splitter-selection unit tests run in-process."""
import numpy as np
import jax.numpy as jnp
import pytest

from _multidev import run_multidev

BODY = """
rng = np.random.default_rng(7)
n = NDEV * (1 << 12)

def check(num_chunks, skew_ands, const=False):
    fn = jax.jit(make_distributed_sort(mesh, "data", slack=2.0,
                                       num_chunks=num_chunks))
    x = rng.integers(0, 2**32 - 1, n, dtype=np.uint32, endpoint=True)
    for _ in range(skew_ands):
        x &= rng.integers(0, 2**32 - 1, n, dtype=np.uint32, endpoint=True)
    if const:
        x[:] = 42
    out, stats = fn(jnp.asarray(x))
    assert not np.asarray(stats.overflow).any(), "capacity overflow"
    assert np.asarray(stats.exchange_attempts)[0] == 1
    got = valid_concat(out, stats.valid)
    assert np.array_equal(np.sort(x), got), f"mismatch chunks={num_chunks}"

check(1, 0)
check(1, 3)               # skewed — splitters must rebalance
check(1, 0, const=True)   # zero entropy: tie-cycling spreads one key level
check(4, 0)               # pipelined
check(4, 2)

# degenerate: num_chunks > n_local leaves empty chunks — must stay
# traceable and report an empty exchange (valid = 0, zero attempts)
fn = jax.jit(make_distributed_sort(mesh, "data", num_chunks=2 * NDEV))
x = rng.integers(0, 2**32, NDEV, dtype=np.uint32)    # n_local = 1 < chunks
out, stats = fn(jnp.asarray(x))
assert np.asarray(stats.valid).sum() == 0
assert not np.asarray(stats.overflow).any()
assert np.asarray(stats.exchange_attempts)[0] == 0
"""


@pytest.mark.slow
@pytest.mark.dist
def test_distributed_sort_multidev():
    run_multidev(BODY)


def test_select_splitters_degenerate_and_regular():
    """Even-rank oversampled selection: degenerate samples (fewer than the
    shard count) repeat sample values instead of striding by 0; regular
    samples pick even quantiles."""
    from repro.core.distributed import _select_splitters

    # degenerate: 0, 1, 3 samples for 8 shards
    s = np.asarray(_select_splitters(jnp.zeros((0,), jnp.uint32), 8))
    assert s.shape == (7,) and np.all(s == 0)
    s = np.asarray(_select_splitters(jnp.full((1,), 5, jnp.uint32), 8))
    assert s.shape == (7,) and np.all(s == 5)
    s = np.asarray(_select_splitters(jnp.arange(5, 8, dtype=jnp.uint32), 8))
    assert s.shape == (7,)
    assert np.all(np.diff(s.astype(np.int64)) >= 0), "monotone"
    assert set(s.tolist()) <= {5, 6, 7}, "drawn from the sample"
    # regular: even quantiles — identical picks to the pre-oversample code
    # on exact-multiple totals, so splitter behaviour is unchanged there
    s = np.asarray(_select_splitters(jnp.arange(64, dtype=jnp.uint32), 8))
    assert s.tolist() == [8, 16, 24, 32, 40, 48, 56]
    # non-multiple total: picks span the WHOLE range (the step::step stride
    # truncated the top total % nshards ranks and starved the last shard)
    s = np.asarray(_select_splitters(jnp.arange(15, dtype=jnp.uint32), 8))
    assert s.tolist() == [(i * 15) // 8 for i in range(1, 8)]


def test_select_splitters_single_shard():
    from repro.core.distributed import _select_splitters
    s = np.asarray(_select_splitters(jnp.arange(9, dtype=jnp.uint32), 1))
    assert s.shape == (0,)
