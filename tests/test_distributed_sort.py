"""Distributed sort (§5 analogue): multi-device tests run in a subprocess so
the fake-device XLA flag never leaks into the rest of the suite."""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.distributed import make_distributed_sort

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(7)

    def check(num_chunks, skew_ands, dtype=np.uint32, const=False):
        fn = jax.jit(make_distributed_sort(mesh, "data", slack=2.0,
                                           num_chunks=num_chunks))
        info = np.iinfo(dtype)
        x = rng.integers(0, info.max, 1 << 15, dtype=dtype, endpoint=True)
        for _ in range(skew_ands):
            x &= rng.integers(0, info.max, 1 << 15, dtype=dtype, endpoint=True)
        if const:
            x[:] = 42
        out, valid, over = map(np.asarray, fn(jnp.asarray(x)))
        per = out.reshape(8, -1)
        got = np.concatenate([per[i][: valid[i]] for i in range(8)])
        assert not over.any(), "capacity overflow"
        assert np.array_equal(np.sort(x), got), f"mismatch chunks={num_chunks}"

    check(1, 0)
    check(1, 3)           # skewed — splitters must rebalance
    check(1, 0, const=True)   # zero entropy
    check(4, 0)           # pipelined
    check(4, 2)

    # degenerate: num_chunks > n_local leaves empty chunks and an empty
    # splitter sample — the step == 0 guard must keep this traceable
    fn = jax.jit(make_distributed_sort(mesh, "data", num_chunks=8))
    x = rng.integers(0, 2**32, 32, dtype=np.uint32)   # n_local = 4 < chunks
    out, valid, over = map(np.asarray, fn(jnp.asarray(x)))
    assert valid.sum() == 0 and not over.any()
    print("DIST-TEST-OK")
""")


@pytest.mark.slow
def test_distributed_sort_8dev():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600, cwd=".")
    assert "DIST-TEST-OK" in res.stdout, res.stdout + res.stderr


def test_select_splitters_degenerate_and_regular():
    """_make_splitters guard: a gathered sample smaller than the shard count
    must not stride by 0; it collapses to a single splitter level instead."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core.distributed import _select_splitters

    # degenerate: 0, 1, 3 samples for 8 shards
    for total in (0, 1, 3):
        s = np.asarray(_select_splitters(
            jnp.arange(5, 5 + total, dtype=jnp.uint32), 8))
        assert s.shape == (7,)
        assert np.all(s == (0 if total == 0 else 5))
    # regular: stride = total // nshards, nshards - 1 picks
    s = np.asarray(_select_splitters(jnp.arange(64, dtype=jnp.uint32), 8))
    assert s.tolist() == [8, 16, 24, 32, 40, 48, 56]
