"""Shared multi-device subprocess harness for the distributed-sort tests.

Multi-device coverage on this CPU container comes from
``--xla_force_host_platform_device_count=N`` (fake host devices).  That flag
must be set before jax initialises, and it must NEVER leak into the main
test process (tests/conftest.py: smoke tests and benches see the single real
CPU device), so every multi-device test body runs in a fresh interpreter
spawned here.  ``run_multidev`` prepends the shared mesh/import preamble,
passes the device count via the child's environment, and asserts the body
reached its final line (the ``MULTIDEV-OK`` print).

The default device count honours an ambient
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` in the *parent*
environment — that is how ``scripts/ci.sh dist`` re-runs the same wall at
8 and 16 devices without duplicating test code.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import textwrap

OK = "MULTIDEV-OK"

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# every body sees: numpy/jax, a 1-axis ("data",) mesh over all devices, the
# distributed-sort API, and NDEV
PREAMBLE = textwrap.dedent("""\
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core.distributed import (DistStats, make_distributed_sort,
                                        valid_concat)
    NDEV = jax.device_count()
    mesh = jax.make_mesh((NDEV,), ("data",))
""")


def env_device_count(default: int = 8) -> int:
    """Device count from an ambient XLA_FLAGS, else ``default``.

    ``scripts/ci.sh dist`` exports the flag to widen the whole wall; the
    plain fast/slow tiers have no ambient flag and get the default.
    """
    m = re.search(r"xla_force_host_platform_device_count=(\d+)",
                  os.environ.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else default


def run_multidev(body: str, ndev: int | None = None, x64: bool = False,
                 timeout: int = 900) -> str:
    """Run ``body`` (appended to PREAMBLE) under ``ndev`` fake devices.

    The body must simply fall off its end on success; uncaught exceptions
    (including assert failures) surface with the child's stderr.  ``x64``
    enables 64-bit jax types (uint64 keys) in the child.
    """
    ndev = env_device_count() if ndev is None else ndev
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    script = PREAMBLE + textwrap.dedent(body) + f'\nprint("{OK}")\n'
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout,
                         cwd=REPO_ROOT)
    assert OK in res.stdout, (
        f"multidev body failed (ndev={ndev})\n--- stdout ---\n{res.stdout}"
        f"\n--- stderr ---\n{res.stderr[-4000:]}")
    return res.stdout
