"""Entropy-adaptive pass elision wall: parity, census, compression.

Three claims are pinned here:

* **Parity** — the adaptive schedule (static live-bit narrowing + mid-sort
  elision of single-occupied-digit passes) is byte-identical to the full
  nominal schedule and to ``engine="argsort"``, across engines, dtypes
  (incl. NaN float keys), KV payloads, the entropy ladder, empty and
  all-equal inputs, and under ``max_passes`` truncation.  An elided pass is
  an identity permutation, so even tie order cannot move.
* **Census** — elision removes *executed launches*, not launch sites: the
  while body still traces to exactly ONE ``pallas_call`` (the launch branch
  of the skip cond; the elide branch launches nothing), and the statically
  unrolled LSD kernel's site count drops with the narrowed window — the
  structural, non-timing proof that dead passes cost nothing.
* **Compression** — ``core.bijection``'s pack/unpack is an order-preserving
  bijection on the live bits, and ``hybrid_sort(compress=True)`` /
  ``oocsort(compress=True)`` round-trip through the packed carrier
  byte-identically (the oocsort spill smoke lives in tests/test_oocsort.py).

The ISSUE 7 acceptance inputs — ``entropy_keys(ands=3)`` and
``clustered_keys`` at n=16384 — are gated below at their natural configs:
AND-ed keys keep every bit live, so their executed-pass win needs the
local-sort threshold the paper's GPU local sort actually has (thousands of
keys), while clustered keys finish early at the small-tile config too.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # hypothesis is an optional test dependency (see pyproject.toml)
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False

from repro.core import SortConfig, hybrid_sort, lsd_sort, model
from repro.core import bijection
from repro.core.hybrid import live_bit_window
from repro.data.distributions import clustered_keys, entropy_keys
from repro.utils import hlo

TCFG = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)
# acceptance config: GPU-realistic local-sort threshold (CUB BlockRadixSort
# handles thousands of keys per block), so the Thearling AND chain goes
# local before the nominal schedule runs out
ACFG = SortConfig(d=8, kpb=256, local_threshold=4096, merge_threshold=2048)
ENGINES = ("argsort", "scan", "kernel")
NOMINAL = model.num_digits(32, 8)


def _aligned_clusters(rng, n, clusters=4, top_shift=24, low_bits=8):
    """Cluster ids in the top digit, live low bytes, dead middle — the
    mid-sort elision shape: segments stay above the local threshold while
    whole digit positions are constant."""
    return ((rng.integers(0, clusters, n).astype(np.uint32)
             << np.uint32(top_shift))
            | rng.integers(0, 1 << low_bits, n).astype(np.uint32))


def _sort(x, eng, adaptive, cfg=TCFG, values=None, **kw):
    out = hybrid_sort(jnp.asarray(x),
                      None if values is None else jnp.asarray(values),
                      cfg=cfg, engine=eng, adaptive=adaptive, **kw)
    return jax.tree.map(np.asarray, out)


# --------------------- acceptance gates (ISSUE 7) ---------------------------

CFG768 = SortConfig(d=8, kpb=256, local_threshold=768, merge_threshold=512)


@pytest.mark.parametrize("make,cfg", [
    pytest.param(lambda r, n: entropy_keys(r, n, 3), ACFG, id="ands3"),
    pytest.param(lambda r, n: clustered_keys(r, n), CFG768, id="clustered"),
])
def test_acceptance_fewer_passes_than_nominal_n16384(rng, make, cfg):
    """At n=16384 the adaptive kernel executes strictly fewer counting
    passes than the nominal ⌈k/d⌉, byte-identically to argsort — and the
    launch census (below) pins one launch per *executed* pass, so fewer
    executed passes IS fewer launches, no timing involved."""
    n = 16384
    x = make(rng, n)
    k, st_ = _sort(x, "kernel", True, cfg=cfg, return_stats=True)
    assert int(st_.counting_passes) < NOMINAL
    assert int(st_.counting_passes) + int(st_.elided_passes) <= NOMINAL
    ka, sa = _sort(x, "argsort", True, cfg=cfg, return_stats=True)
    assert k.tobytes() == ka.tobytes()
    assert np.array_equal(k, np.sort(x))
    # the engines ran the same adaptive schedule, not merely the same sort
    assert tuple(int(s) for s in st_) == tuple(int(s) for s in sa)


def test_census_one_launch_site_per_executed_pass():
    """Adaptive loop body: ONE pallas_call site — it lives in the launch
    branch of the skip cond and the elide branch has none, so runtime
    launches == executed passes.  Total sites stay prologue + pass + local
    classes, exactly the non-adaptive census."""
    from repro.core.hybrid import local_sort_classes
    for n in (257, 4096, 20000):
        jx = jax.make_jaxpr(
            lambda a: hybrid_sort(a, cfg=TCFG, engine="kernel",
                                  adaptive=True))(jnp.zeros(n, jnp.uint32))
        assert hlo.while_body_pallas_launches(jx) == [1], n
        assert hlo.pallas_launch_count(jx) == \
            2 + len(local_sort_classes(n, TCFG)), n


def test_census_lsd_narrowed_window_drops_launch_sites(rng):
    """The statically unrolled LSD kernel is the trace-level proof that
    dead bits elide whole launches: 16 dead high bits remove two of the
    five launch sites (⌈16/8⌉ + prologue left)."""
    x = (np.uint32(0xABCD) << np.uint32(16)) \
        | rng.integers(0, 1 << 16, 2048).astype(np.uint32)
    jx = jax.make_jaxpr(
        lambda: lsd_sort(x, d=8, engine="kernel", kpb=512))()
    assert hlo.pallas_launch_count(jx) == model.num_digits(16, 8) + 1 == 3
    jx_full = jax.make_jaxpr(
        lambda: lsd_sort(x, d=8, engine="kernel", kpb=512,
                         adaptive=False))()
    assert hlo.pallas_launch_count(jx_full) == NOMINAL + 1 == 5
    k, passes = lsd_sort(jnp.asarray(x), d=8, return_passes=True)
    assert passes == 2 and np.array_equal(np.asarray(k), np.sort(x))


def test_mid_sort_elision_fires_and_stats_agree(rng):
    """Aligned clusters: the dead middle digit is elided mid-sort off the
    fused launch's free next-pass histogram — executed + elided < nominal
    executed-without-adaptivity, identical stats across all engines."""
    x = _aligned_clusters(rng, 3000)
    ref = None
    for eng in ENGINES:
        k, st_ = _sort(x, eng, True, return_stats=True)
        got = (k.tobytes(), tuple(int(s) for s in st_))
        ref = ref or got
        assert got == ref, eng
    assert np.array_equal(k, np.sort(x))
    assert int(st_.elided_passes) >= 1
    ks, ss = _sort(x, "kernel", False, return_stats=True)
    assert ks.tobytes() == k.tobytes()
    assert int(ss.counting_passes) > int(st_.counting_passes)
    assert int(ss.elided_passes) == 0


# --------------------- parity wall ------------------------------------------

def _wall_keys(rng, dtype, n, shape):
    if dtype == np.float32:
        x = (rng.standard_normal(n) * 1e3).astype(np.float32)
        if n >= 8:
            x[:6] = [0.0, -0.0, np.inf, -np.inf, np.nan, -np.nan]
        return x
    if shape == "entropy":
        return entropy_keys(rng, n, 3, dtype=dtype)
    if shape == "clustered":
        return clustered_keys(rng, n, dtype=dtype)
    if shape == "allequal":
        return np.full(n, np.iinfo(dtype).max // 3, dtype)
    info = np.iinfo(dtype)
    return rng.integers(info.min, info.max, n, endpoint=True).astype(dtype)


@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32])
@pytest.mark.parametrize("shape", ["uniform", "entropy", "clustered",
                                   "allequal"])
def test_adaptive_parity_grid(rng, dtype, shape):
    """Deterministic fast-tier wall: adaptive on/off x engines x dtypes
    (NaN floats included) x entropy/clustered/all-equal, byte-identical
    keys AND values."""
    n = 1500
    x = _wall_keys(rng, dtype, n, shape)
    v = np.arange(n, dtype=np.int32)
    ref = None
    for eng in ENGINES:
        for adaptive in (True, False):
            k, v_ = _sort(x, eng, adaptive, values=v)
            got = (k.tobytes(), v_.tobytes())
            ref = ref or got
            assert got == ref, (eng, adaptive)


@pytest.mark.parametrize("adaptive", [True, False])
def test_adaptive_empty_and_tiny(adaptive):
    for n in (0, 1, 2):
        x = np.arange(n, dtype=np.uint32)[::-1].copy()
        for eng in ENGINES:
            k, st_ = _sort(x, eng, adaptive, return_stats=True)
            assert np.array_equal(k, np.sort(x)), (eng, n)
            assert int(st_.elided_passes) >= 0


def test_max_passes_interaction(rng):
    """max_passes caps pass *slots* (executed + elided), so truncated
    adaptive results stay identical across engines and an elided slot
    cannot smuggle extra progress past the cap."""
    x = rng.integers(0, 2**32, 4000, dtype=np.uint32)
    x[:2000] &= 0x00FFFFFF
    outs = []
    for eng in ENGINES:
        for adaptive in (True, False):
            k, st_ = _sort(x, eng, adaptive, max_passes=1, return_stats=True)
            outs.append((eng, adaptive, k, st_))
            assert int(st_.counting_passes) + int(st_.elided_passes) <= 1
    ref = outs[0][2]
    for eng, adaptive, k, _ in outs:
        assert k.tobytes() == ref.tobytes(), (eng, adaptive)
    assert not np.array_equal(ref, np.sort(x))     # 1 pass can't finish
    assert np.array_equal(np.sort(ref), np.sort(x))


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(deadline=None, max_examples=20,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_adaptive_parity_hypothesis_wall(data):
        """Randomised cross-product the grid can't cover: every engine x
        adaptive on/off is byte-identical (keys, values) on arbitrary
        dtype/shape/size/seed draws."""
        dtype = data.draw(st.sampled_from([np.uint32, np.int32, np.float32]),
                          label="dtype")
        shape = data.draw(st.sampled_from(
            ["uniform", "entropy", "clustered", "allequal"]), label="shape")
        n = data.draw(st.sampled_from([0, 1, 3, 63, 257, 1000]), label="n")
        kv = data.draw(st.booleans(), label="kv")
        seed = data.draw(st.integers(0, 2**16), label="seed")
        rng = np.random.default_rng(seed)
        x = _wall_keys(rng, dtype, n, shape)
        v = np.arange(n, dtype=np.int32) if kv else None
        ref = None
        for eng in ENGINES:
            for adaptive in (True, False):
                out = _sort(x, eng, adaptive, values=v, return_stats=True)
                if kv:
                    k, v_, _ = out
                else:
                    (k, _), v_ = out, np.empty(0)
                got = (k.tobytes(), v_.tobytes())
                ref = ref or got
                assert got == ref, (eng, adaptive)


# --------------------- live-bit window / compression ------------------------

def test_live_bit_window():
    assert live_bit_window(np.array([], np.uint32)) == (0, 0)
    assert live_bit_window(np.full(5, 0xF0F0, np.uint32)) == (0, 0)
    # 0b1010_0000 vs 0b1110_0000: only bit 6 varies -> window [6, 7)
    assert live_bit_window(
        np.array([0b1010_0000, 0b1110_0000], np.uint32)) == (6, 7)
    assert live_bit_window(np.array([0, 1], np.uint64)) == (0, 1)


def test_compression_plan_and_roundtrip(rng):
    ub = rng.integers(0, 1 << 12, 500).astype(np.uint64) << np.uint64(13)
    ub |= np.uint64(0b101) << np.uint64(40)    # dead set bits
    plan = bijection.compression_plan_np(ub)
    assert plan.source_bits == 64
    assert plan.packed_bits <= 12
    assert np.dtype(bijection.packed_carrier_dtype(plan)) == np.uint16
    packed = bijection.pack_ordered_bits_np(ub, plan)
    assert packed.dtype == np.uint16
    back = bijection.unpack_ordered_bits_np(packed, plan)
    assert np.array_equal(back, ub)
    # order preservation: the pack is monotone on the live bits
    order = np.argsort(ub, kind="stable")
    assert np.array_equal(packed[order], np.sort(packed))
    # jnp mirror agrees (32-bit-safe carrier side only without x64)
    ub32 = (ub >> np.uint64(13)).astype(np.uint32)
    plan32 = bijection.compression_plan_np(ub32)
    p_np = bijection.pack_ordered_bits_np(ub32, plan32)
    p_j = np.asarray(bijection.pack_ordered_bits(jnp.asarray(ub32), plan32))
    assert np.array_equal(p_np, p_j)
    assert np.array_equal(
        np.asarray(bijection.unpack_ordered_bits(jnp.asarray(p_j), plan32)),
        ub32)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=40)
    @given(mask=st.integers(1, (1 << 32) - 1), seed=st.integers(0, 2**16))
    def test_compression_order_preserving_hypothesis(mask, seed):
        """For ANY live-bit mask, packing is a strictly monotone bijection
        of the masked values: sort order and equality survive compression."""
        rng = np.random.default_rng(seed)
        dead = rng.integers(0, 1 << 32, dtype=np.uint32) & ~np.uint32(mask)
        ub = ((rng.integers(0, 1 << 32, 200, dtype=np.uint32)
               & np.uint32(mask)) | dead)
        plan = bijection.CompressionPlan(mask=int(mask), dead=int(dead),
                                         source_bits=32)
        packed = bijection.pack_ordered_bits_np(ub, plan)
        assert np.array_equal(
            bijection.unpack_ordered_bits_np(packed, plan), ub)
        srt = np.argsort(ub, kind="stable")
        assert np.array_equal(packed[srt], np.sort(packed))
        same = ub[:, None] == ub[None, :]
        assert np.array_equal(packed[:, None] == packed[None, :], same)


def test_hybrid_sort_compressed_keys(rng):
    x = _aligned_clusters(rng, 2000)
    k, st_ = _sort(x, "kernel", True, compress=True, return_stats=True)
    ka = _sort(x, "argsort", True)
    assert k.tobytes() == ka.tobytes()
    assert np.array_equal(k, np.sort(x))
    # 10 live bits sort in one d=8-window pass or two
    assert int(st_.counting_passes) + int(st_.elided_passes) <= 2


@pytest.mark.slow
def test_hybrid_sort_compressed_uint64(rng):
    from jax.experimental import enable_x64
    with enable_x64():
        x = (rng.integers(0, 1 << 10, 2500).astype(np.uint64)
             << np.uint64(30)) | np.uint64(1 << 60)
        k, st_ = _sort(x, "kernel", True, compress=True, return_stats=True)
        assert np.array_equal(k, np.sort(x))
        assert int(st_.counting_passes) <= 2      # 10 live bits, not 64
        ks = _sort(x, "argsort", False)
        assert ks.tobytes() == k.tobytes()


def test_compress_requires_concrete_keys():
    with pytest.raises(ValueError, match="compress"):
        jax.jit(lambda a: hybrid_sort(a, cfg=TCFG, compress=True))(
            jnp.zeros(64, jnp.uint32))


def test_lsd_adaptive_parity_and_pass_counts(rng):
    x = (np.uint32(0xBEEF) << np.uint32(16)) \
        | rng.integers(0, 1 << 16, 3000).astype(np.uint32)
    v = np.arange(3000, dtype=np.int32)
    ref = None
    for eng in ENGINES:
        for adaptive in (True, False):
            k, v_ = lsd_sort(jnp.asarray(x), jnp.asarray(v), d=8, engine=eng,
                             kpb=512, adaptive=adaptive)
            got = (np.asarray(k).tobytes(), np.asarray(v_).tobytes())
            ref = ref or got
            assert got == ref, (eng, adaptive)
    assert np.array_equal(np.asarray(k), np.sort(x))
