"""Bijection hardening wall: float special values through the §4.6 key map.

Pins the IEEE-754 totalOrder semantics the whole pipeline inherits —
``hybrid_sort`` encodes keys to ordered bits before any pass and ``oocsort``
merges runs bitwise, so NaNs (any payload, either sign), ±0.0, ±inf and
denormals must encode monotonically and round-trip bit-exactly or float
keys silently corrupt.  Also pins the numpy mirrors
(``to_ordered_bits_np``/``from_ordered_bits_np``) bit-for-bit against the
jit versions: the host-spill path and checksum layer trust them.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.bijection import (carrier_dtype, from_ordered_bits,
                                  from_ordered_bits_np, to_ordered_bits,
                                  to_ordered_bits_np)
from repro.core.outofcore import oocsort

# bit patterns in strict totalOrder: -NaN (max payload) < -NaN (quiet) <
# -inf < -max < -1 < -denormal < -0.0 < +0.0 < +denormal < 1 < +max <
# +inf < +NaN (quiet) < +NaN (max payload)
_TOTAL_ORDER_BITS = {
    np.float32: np.array([
        0xFFFFFFFF, 0xFFC00000, 0xFF800000, 0xFF7FFFFF, 0xBF800000,
        0x80000001, 0x80000000, 0x00000000, 0x00000001, 0x3F800000,
        0x7F7FFFFF, 0x7F800000, 0x7FC00000, 0x7FFFFFFF], dtype=np.uint32),
    np.float64: np.array([
        0xFFFFFFFFFFFFFFFF, 0xFFF8000000000000, 0xFFF0000000000000,
        0xFFEFFFFFFFFFFFFF, 0xBFF0000000000000, 0x8000000000000001,
        0x8000000000000000, 0x0000000000000000, 0x0000000000000001,
        0x3FF0000000000000, 0x7FEFFFFFFFFFFFFF, 0x7FF0000000000000,
        0x7FF8000000000000, 0x7FFFFFFFFFFFFFFF], dtype=np.uint64),
}


def _specials(fdtype):
    return _TOTAL_ORDER_BITS[fdtype].view(fdtype)


@pytest.mark.parametrize("fdtype", [np.float32, np.float64])
def test_float_total_order_monotone_and_bit_exact(fdtype):
    import jax
    if fdtype is np.float64 and not jax.config.jax_enable_x64:
        pytest.skip("float64 keys require jax_enable_x64")
    x = _specials(fdtype)
    enc = np.asarray(to_ordered_bits(jnp.asarray(x)))
    # strictly increasing: NaNs at deterministic extremes by sign, payload-
    # ordered; -0.0 strictly below +0.0; denormals between zero and ±1
    assert (np.diff(enc.astype(np.uint64)) > 0).all(), enc
    back = np.asarray(from_ordered_bits(jnp.asarray(enc), fdtype))
    assert back.tobytes() == x.tobytes()        # bit-exact incl. NaN payloads


def test_negative_zero_round_trip_and_order():
    z = np.array([-0.0, 0.0], np.float32)
    enc = np.asarray(to_ordered_bits(jnp.asarray(z)))
    assert enc[0] < enc[1]                      # -0.0 sorts strictly below
    back = np.asarray(from_ordered_bits(jnp.asarray(enc), np.float32))
    assert np.signbit(back[0]) and not np.signbit(back[1])
    assert back.tobytes() == z.tobytes()


@pytest.mark.parametrize("dtype", [np.uint16, np.int32, np.float32])
def test_numpy_mirror_matches_jit_bijection(rng, dtype):
    # random bit patterns cover NaNs/infs/denormals for the float case
    raw = rng.integers(0, 1 << 32, 512, dtype=np.uint64)
    bits = raw.astype(np.dtype(dtype).str.replace("f", "u").replace("i", "u"))
    x = bits.view(dtype)
    np_enc = to_ordered_bits_np(x)
    jit_enc = np.asarray(to_ordered_bits(jnp.asarray(x)))
    assert np_enc.tobytes() == jit_enc.tobytes()
    assert np_enc.dtype == np.dtype(carrier_dtype(dtype))
    np_back = from_ordered_bits_np(np_enc, dtype)
    jit_back = np.asarray(from_ordered_bits(jnp.asarray(jit_enc), dtype))
    assert np_back.tobytes() == x.tobytes()     # bijection, bit-exact
    assert jit_back.tobytes() == x.tobytes()


def test_numpy_mirror_rejects_unsupported():
    with pytest.raises(TypeError):
        to_ordered_bits_np(np.zeros(2, np.complex64))


@pytest.mark.parametrize("spill", [False, True])
def test_nan_keys_through_oocsort(rng, spill):
    """NaN/±0 float keys survive the full §5 pipeline in totalOrder."""
    n = 1024
    x = rng.normal(size=n).astype(np.float32)
    # salt in both NaN signs (distinct payloads), ±inf and both zeros
    salt = _specials(np.float32)
    x[rng.choice(n, salt.shape[0], replace=False)] = salt
    kwargs = dict(spill_budget_bytes=4096) if spill else {}
    out = oocsort(x, 300, tile=16, engine="argsort", **kwargs)
    expected = from_ordered_bits_np(np.sort(to_ordered_bits_np(x)),
                                    np.float32)
    assert out.tobytes() == expected.tobytes()  # bit-exact incl. NaN payload
