"""Analyzer tests: the full contract sweep is green, and every seeded
contract violation (mutation) is detected.

The mutations are the failure modes the analyzer exists to catch: an extra
launch smuggled into the pass loop, a dropped ping-pong alias, overlapping
or gappy scatter ranges, an out-of-bounds block load, a read-after-write in
a kernel body, a comparison sort hidden in an engine, an undeclared extra
HBM sweep, global-PRNG use, and an undonated dispatch.  Each mutation test
also carries the unmutated positive control, so a check that silently
flags everything (or nothing) fails here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.analysis import census, contracts, donation, lint, refhazard
from repro.analysis import expr, transfer
from repro.analysis.trace import (collect_pallas_sites, ref_access_counts,
                                  sort_primitive_count)


# --------------------------------------------------------------------------
# the green path

def test_full_contract_sweep_is_green():
    """Every registered entry point verifies against its declaration."""
    reports = contracts.run_all()
    bad = [f for r in reports for f in r.findings]
    assert not bad, "\n".join(bad)
    assert {r.name for r in reports} >= {
        "hybrid_sort", "hybrid_sort_kv", "lsd_sort", "single_pass_partition",
        "moe_dispatch", "pipeline_bucketing", "ooc_chunk_sort",
        "ooc_merge_round", "ooc_slab_sweep", "distributed_shard",
        "descriptor_tables"}


def test_repo_lint_is_green():
    import os
    import repro.analysis
    src_root = os.path.dirname(os.path.dirname(
        os.path.abspath(repro.analysis.__file__)))
    assert lint.run_lint(src_root) == []


def test_expected_census_matches_formulas():
    p = contracts.hybrid_params(2048, contracts.TCFG)
    got = contracts.expected_census("hybrid_sort", p)
    assert got["total"] == 2 + p["classes"]
    assert got["while_bodies"] == [1]


def test_expr_evaluator_rejects_unsafe_forms():
    assert expr.evaluate("ceil_div(7, 2) + 1", {}) == 5
    assert expr.evaluate("[1] * chunks", {"chunks": 3}) == [1, 1, 1]
    for bad in ("__import__('os')", "(lambda: 1)()", "x.__class__",
                "open('/etc/passwd')"):
        with pytest.raises(expr.FormulaError):
            expr.evaluate(bad, {"x": 1})


# --------------------------------------------------------------------------
# mutation helpers: tiny pallas programs with seeded violations

def _noop(x):
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]
    return pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True)(x)


def _pingpong(x, alt, donate):
    """Full-buffer rewrite through an alternate buffer — the ping-pong
    shape; ``donate=False`` is the dropped-alias mutation."""
    def kernel(x_ref, alt_ref, o_ref):
        o_ref[...] = x_ref[...] + 1

    kwargs = {"input_output_aliases": {1: 0}} if donate else {}
    return pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True, **kwargs)(x, alt)


# M1: extra launch inside the pass loop's while body
def test_mutation_extra_launch_in_while_body():
    def prog(x):
        def body(c):
            i, v = c
            return i + 1, _noop(_noop(v))
        return jax.lax.while_loop(lambda c: c[0] < 3, body, (0, x))

    jx = jax.make_jaxpr(prog)(jnp.zeros(8, jnp.float32))
    sites = collect_pallas_sites(jx)
    decl = {"launch_total": "2", "while_body_launches": "[1]"}
    findings = census.check_census(jx, sites, decl, {})
    assert any("while-body launches" in f for f in findings), findings


# M2: extra top-level launch
def test_mutation_extra_toplevel_launch():
    jx = jax.make_jaxpr(lambda x: _noop(_noop(x)))(jnp.zeros(8, jnp.float32))
    sites = collect_pallas_sites(jx)
    ok = census.check_census(jx, sites,
                             {"launch_total": "2",
                              "while_body_launches": "[]"}, {})
    assert ok == []
    bad = census.check_census(jx, sites,
                              {"launch_total": "1",
                               "while_body_launches": "[]"}, {})
    assert any("launch total" in f for f in bad), bad


# M3: dropped ping-pong alias -> silent copy
def test_mutation_dropped_alias_silent_copy():
    x = jnp.zeros(64, jnp.uint32)
    for donate, nfind in ((True, 0), (False, 1)):
        jx = jax.make_jaxpr(
            lambda a, b, d=donate: _pingpong(a, b, d))(x, x)
        (site,) = collect_pallas_sites(jx)
        findings = donation.audit_site(site)
        assert len(findings) == nfind, (donate, findings)
        if not donate:
            assert "silently copies" in findings[0]
    # declared alias-count check catches it too
    jx = jax.make_jaxpr(lambda a, b: _pingpong(a, b, False))(x, x)
    sites = collect_pallas_sites(jx)
    bad = donation.check_donation(sites, {"kernel": "1"}, {})
    assert any("expected 1 alias pair" in f for f in bad), bad


# M4/M5: overlapping scatter ranges / coverage gap in the merge tables
def test_mutation_merge_table_overlap_and_gap():
    good = ([0, 16, 32, 48], [16, 16, 16, 16])
    overlap = ([0, 8, 32, 48], [16, 16, 16, 16])
    gap = ([0, 16, 40, 48], [16, 16, 8, 16])
    ws = np.zeros((16,), np.int32)
    wt_rows = np.zeros((4, 4), np.int32)

    def check(oo, oc):
        wt = wt_rows.copy()
        wt[:, 0] = oc
        return refhazard.check_merge_tables(
            np.array(oo, np.int32), np.array(oc, np.int32), ws,
            wt.reshape(-1), kway=4, tpb=16, n=64, buf_len=80)

    assert check(*good) == []
    assert any("overlap" in f for f in check(*overlap))
    assert any("expected exactly [0, 64)" in f for f in check(*gap))


# M10: block load overrunning the padded buffer
def test_mutation_fused_table_load_overrun():
    m, kpb, B = 1000, 128, 4
    import repro.core.plan as plan
    from repro.kernels.fused import pad_length
    blocks = plan.make_region_blocks(
        jnp.zeros((1,), jnp.int32), jnp.full((1,), m, jnp.int32), m, kpb,
        plan.max_region_blocks(m, kpb, 1), batch=B)
    n_pad = pad_length(m, kpb)
    assert refhazard.check_fused_tables(blocks, m, kpb, n_pad) == []
    # seed an offset past the pad: the load [off, off+kpb) escapes
    bad = blocks._replace(offset=blocks.offset.at[0, 0].set(n_pad - 1))
    findings = refhazard.check_fused_tables(bad, m, kpb, n_pad)
    assert any("outside padded buffer" in f for f in findings), findings


# M6: smuggled comparison sort
def test_mutation_smuggled_sort():
    jx = jax.make_jaxpr(lambda x: jnp.sort(x))(jnp.zeros(32, jnp.float32))
    assert sort_primitive_count(jx) == 1
    jx = jax.make_jaxpr(lambda x: x + 1)(jnp.zeros(32, jnp.float32))
    assert sort_primitive_count(jx) == 0
    src = ("import jax.numpy as jnp\n"
           "def rank(keys):\n"
           "    return jnp.argsort(keys)\n")
    findings = lint.lint_source(src, "kernels/evil.py",
                                ["no-comparison-sort"])
    assert len(findings) == 1 and findings[0].rule == "no-comparison-sort"


# M7: global-PRNG use in the data layer
def test_mutation_global_prng():
    bad = ("import numpy as np\n"
           "def draw(n):\n"
           "    return np.random.randint(0, 5, n)\n")
    good = ("import numpy as np\n"
            "def draw(n, seed):\n"
            "    return np.random.default_rng(seed).integers(0, 5, n)\n")
    assert [f.rule for f in lint.lint_source(bad, "data/evil.py",
                                             ["no-global-prng"])] \
        == ["no-global-prng"]
    assert lint.lint_source(good, "data/fine.py", ["no-global-prng"]) == []


# M8: undeclared extra HBM sweep
def test_mutation_extra_sweep_changes_transfer_bytes():
    fn, args, params = contracts.REGISTRY["single_pass_partition"].make()
    jx = jax.make_jaxpr(fn)(*args)
    sites = collect_pallas_sites(jx)
    decl = contracts.REGISTRY["single_pass_partition"].decl["transfer"]
    assert transfer.check_hbm_bytes(sites, decl, params) == []
    # mutate the nominal schedule (an undeclared extra pass = extra sweeps)
    bad = dict(params)
    bad["passes"] = params["passes"] + 1
    findings = transfer.check_hbm_bytes(sites, decl, bad)
    assert any("HBM sweep bytes" in f for f in findings), findings


# M9: read-after-write on a scatter target inside a kernel body
def test_mutation_raw_hazard_in_kernel():
    def raw_kernel(i_ref, x_ref, o_ref):
        o_ref[pl.ds(i_ref[0], 4)] = x_ref[pl.ds(0, 4)]
        y = o_ref[pl.ds(i_ref[0], 4)]         # read-back of a dynamic write
        o_ref[pl.ds(4, 4)] = y + 1

    def prog(i, x):
        return pl.pallas_call(
            raw_kernel, out_shape=jax.ShapeDtypeStruct((16,), jnp.float32),
            interpret=True)(i, x)

    jx = jax.make_jaxpr(prog)(jnp.zeros(2, jnp.int32),
                              jnp.zeros(16, jnp.float32))
    (site,) = collect_pallas_sites(jx)
    findings = refhazard.check_kernel(site)
    assert any("read-after-write" in f for f in findings), findings


# M11: alt_* dispatch without donation (source level)
def test_mutation_undonated_dispatch_lint():
    bad = ("from jax.experimental import pallas as pl\n"
           "def sweep(src, alt_keys):\n"
           "    return pl.pallas_call(k, out_shape=o)(src, alt_keys)\n")
    good = ("from jax.experimental import pallas as pl\n"
            "def sweep(src, alt_keys):\n"
            "    return pl.pallas_call(k, out_shape=o,\n"
            "                          input_output_aliases={1: 0})(\n"
            "        src, alt_keys)\n")
    assert [f.rule for f in lint.lint_source(bad, "kernels/evil.py",
                                             ["undonated-dispatch"])] \
        == ["undonated-dispatch"]
    assert lint.lint_source(good, "kernels/fine.py",
                            ["undonated-dispatch"]) == []


# the trace layer itself: ref accounting sees through pl.when conds
def test_ref_access_counts_on_real_fused_kernel():
    fn, args, _ = contracts.REGISTRY["single_pass_partition"].make()
    jx = jax.make_jaxpr(fn)(*args)
    fused_sites = [s for s in collect_pallas_sites(jx)
                   if s.name == "_fused_pass_kernel"]
    assert fused_sites
    site = fused_sites[0]
    counts = ref_access_counts(site.kernel_jaxpr)
    # the donated alternate buffers are never read or written in the body
    for opi in site.aliases:
        assert counts.get(site.root_of_operand(opi), (0, 0)) == (0, 0)
    # the source key buffer is read (batch block loads), never written
    gets, swaps = counts[site.num_scalars]
    assert gets > 0 and swaps == 0
