"""Core hybrid radix sort: correctness, paper invariants, property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # hypothesis is an optional test dependency (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        @staticmethod
        def lists(*_a, **_k):
            return None

        @staticmethod
        def integers(*_a, **_k):
            return None

        @staticmethod
        def floats(*_a, **_k):
            return None

from repro.core import (hybrid_sort, lsd_sort, SortConfig, memory_budget,
                        expected_speedup, to_ordered_bits, from_ordered_bits)
from repro.core import model as sort_model
from repro.core.ranks import (stable_partition_dest_argsort,
                              stable_partition_dest_scan)
from repro.core.segmented import (counting_partition, capacity_dispatch,
                                  merge_sorted, multiway_merge)
from conftest import entropy_keys

# small thresholds so counting passes + merging actually exercise at test sizes
TCFG = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)


# --------------------------- bijections ------------------------------------

@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32,
                                   np.uint8, np.int16, np.uint16])
def test_bijection_roundtrip_and_order(rng, dtype):
    if np.issubdtype(dtype, np.floating):
        x = rng.standard_normal(4096).astype(dtype) * 100
    else:
        info = np.iinfo(dtype)
        x = rng.integers(info.min, info.max, 4096, endpoint=True).astype(dtype)
    u = to_ordered_bits(jnp.asarray(x))
    back = np.asarray(from_ordered_bits(u, dtype))
    assert np.array_equal(back, x)
    order_u = np.argsort(np.asarray(u), kind="stable")
    order_x = np.argsort(x, kind="stable")
    assert np.array_equal(x[order_u], x[order_x])


# --------------------------- rank engines ----------------------------------

@pytest.mark.parametrize("num_buckets", [2, 16, 256])
def test_rank_engines_agree(rng, num_buckets):
    ids = jnp.asarray(rng.integers(0, num_buckets, 5000).astype(np.int32))
    a = stable_partition_dest_argsort(ids)
    b = stable_partition_dest_scan(ids, num_buckets, chunk=512)
    assert np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------- hybrid sort -----------------------------------

@pytest.mark.parametrize("n", [0, 1, 2, 3, 47, 48, 49, 1000, 20000])
def test_hybrid_sizes(rng, n):
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    out = hybrid_sort(jnp.asarray(x), cfg=TCFG)
    assert np.array_equal(np.sort(x), np.asarray(out))


@pytest.mark.parametrize("ands", [0, 1, 3, 8])
def test_hybrid_entropy_sweep(rng, ands):
    x = entropy_keys(rng, 8192, ands)
    out = hybrid_sort(jnp.asarray(x), cfg=TCFG)
    assert np.array_equal(np.sort(x), np.asarray(out))


def test_hybrid_constant_runs_all_passes(rng):
    # zero-entropy keys: the adaptive schedule sees no live bits and plans
    # ZERO passes; with adaptive=False the 32/8 worst case runs all 4.
    x = np.full(5000, 0xDEADBEEF, dtype=np.uint32)
    out, stats = hybrid_sort(jnp.asarray(x), cfg=TCFG, return_stats=True)
    assert np.array_equal(x, np.asarray(out))
    assert int(stats.counting_passes) == 0
    assert int(stats.elided_passes) == 0
    assert not bool(stats.used_local_sort)
    out, stats = hybrid_sort(jnp.asarray(x), cfg=TCFG, return_stats=True,
                             adaptive=False)
    assert np.array_equal(x, np.asarray(out))
    assert int(stats.counting_passes) == 4          # 32/8: zero-entropy worst case
    assert not bool(stats.used_local_sort)


def test_hybrid_uniform_finishes_early(rng):
    # enough keys that pass 1 leaves buckets > threshold, pass 2 finishes
    x = rng.integers(0, 2**32, 50000, dtype=np.uint32)
    out, stats = hybrid_sort(jnp.asarray(x), cfg=TCFG, return_stats=True)
    assert np.array_equal(np.sort(x), np.asarray(out))
    assert int(stats.counting_passes) < 4           # local sort saves passes
    assert bool(stats.used_local_sort)
    assert int(stats.max_segment) <= TCFG.local_threshold


def test_hybrid_pairs_move_together(rng):
    x = entropy_keys(rng, 6000, 2)
    v = np.arange(6000, dtype=np.int32)
    ks, vs = hybrid_sort(jnp.asarray(x), jnp.asarray(v), cfg=TCFG)
    ks, vs = np.asarray(ks), np.asarray(vs)
    assert np.array_equal(np.sort(x), ks)
    assert np.array_equal(x[vs], ks)                # value-consistency (not stability)


def test_hybrid_value_pytree(rng):
    x = rng.integers(0, 2**16, 512, dtype=np.uint32)
    vals = {"a": jnp.arange(512, dtype=jnp.int32),
            "b": jnp.arange(512, dtype=jnp.float32) * 2}
    ks, vs = hybrid_sort(jnp.asarray(x), vals, cfg=TCFG)
    assert np.array_equal(np.asarray(vs["a"]) * 2.0, np.asarray(vs["b"]))


@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_hybrid_signed_and_float(rng, dtype):
    if dtype == np.float32:
        x = np.concatenate([rng.standard_normal(3000).astype(dtype) * 1e3,
                            np.array([0.0, -0.0, np.inf, -np.inf], dtype)])
    else:
        x = rng.integers(-2**31, 2**31 - 1, 3000).astype(dtype)
    out = np.asarray(hybrid_sort(jnp.asarray(x), cfg=TCFG))
    assert np.array_equal(np.sort(x), out)


def test_hybrid_segment_bound_I3(rng):
    n = 30000
    x = entropy_keys(rng, n, 1)
    _, stats = hybrid_sort(jnp.asarray(x), cfg=TCFG, return_stats=True)
    assert int(stats.num_segments) <= sort_model.max_total_buckets(n, TCFG)


def test_memory_budget_under_5_percent():
    # paper §4.5: 2 GB of u32 keys with KPB=6912, ∂̂=9216, ∂=3000 -> aux < 5% of M1
    from repro.core import default_config
    b = memory_budget(500_000_000, 32, default_config(4))
    assert b["aux_over_m1"] < 0.05


def test_expected_speedups_match_paper():
    assert abs(expected_speedup(32) - 1.75) < 1e-6     # 7 vs 4 passes
    assert abs(expected_speedup(64) - 1.625) < 1e-6    # 13 vs 8 passes


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2**32 - 1), min_size=0, max_size=400),
       st.integers(1, 6))
def test_hybrid_property_vs_npsort(xs, dbits):
    x = np.asarray(xs, dtype=np.uint32)
    cfg = SortConfig(d=dbits, kpb=32, local_threshold=16, merge_threshold=8)
    out = hybrid_sort(jnp.asarray(x), cfg=cfg)
    assert np.array_equal(np.sort(x), np.asarray(out))


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(allow_nan=False, width=32), min_size=0, max_size=300))
def test_hybrid_property_floats(xs):
    x = np.asarray(xs, dtype=np.float32)
    out = hybrid_sort(jnp.asarray(x), cfg=TCFG)
    assert np.array_equal(np.sort(x), np.asarray(out))


# --------------------------- LSD baseline ----------------------------------

@pytest.mark.parametrize("d", [2, 4, 5, 7, 8])
def test_lsd_digit_widths(rng, d):
    x = rng.integers(0, 2**32, 3000, dtype=np.uint32)
    assert np.array_equal(np.sort(x), np.asarray(lsd_sort(jnp.asarray(x), d=d)))


def test_lsd_is_stable(rng):
    # LSD with values: equal keys keep input order (the property MSD drops)
    x = rng.integers(0, 8, 2000).astype(np.uint32)   # many duplicates
    v = np.arange(2000, dtype=np.int32)
    ks, vs = lsd_sort(jnp.asarray(x), jnp.asarray(v), d=2)
    vs = np.asarray(vs)
    for key in range(8):
        grp = vs[np.asarray(ks) == key]
        assert (np.diff(grp) > 0).all()


# --------------------------- partition / merge ------------------------------

def test_counting_partition_groups(rng):
    ids = rng.integers(0, 16, 4096).astype(np.int32)
    part = counting_partition(jnp.asarray(ids), 16)
    sorted_ids = np.asarray(ids)[np.asarray(part.perm)]
    assert (np.diff(sorted_ids) >= 0).all()
    assert np.array_equal(np.asarray(part.counts), np.bincount(ids, minlength=16))


def test_capacity_dispatch_drops_overflow(rng):
    ids = np.zeros(100, np.int32)                    # all to bucket 0
    cd = capacity_dispatch(jnp.asarray(ids), 4, 32)
    assert int(np.asarray(cd.kept).sum()) == 32
    assert np.asarray(cd.slot_valid)[0].sum() == 32
    assert np.asarray(cd.slot_valid)[1:].sum() == 0


def test_merge_sorted_and_multiway(rng):
    a = np.sort(rng.integers(0, 1000, 257).astype(np.uint32))
    b = np.sort(rng.integers(0, 1000, 511).astype(np.uint32))
    m = np.asarray(merge_sorted(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(np.sort(np.concatenate([a, b])), m)
    runs = np.sort(rng.integers(0, 2**20, (8, 128)).astype(np.uint32), axis=1)
    mm = np.asarray(multiway_merge(jnp.asarray(runs)))
    assert np.array_equal(np.sort(runs.reshape(-1)), mm)


def test_merge_sorted_with_values(rng):
    a = np.sort(rng.integers(0, 500, 100).astype(np.uint32))
    b = np.sort(rng.integers(0, 500, 150).astype(np.uint32))
    va = np.arange(100, dtype=np.int32)
    vb = np.arange(1000, 1150, dtype=np.int32)
    m, vm = merge_sorted(jnp.asarray(a), jnp.asarray(b),
                         jnp.asarray(va), jnp.asarray(vb))
    m, vm = np.asarray(m), np.asarray(vm)
    assert np.array_equal(np.sort(np.concatenate([a, b])), m)
    src = np.concatenate([a, b])
    vals = np.concatenate([va, vb])
    assert np.array_equal(src[np.searchsorted(np.arange(0), [])] if False else
                          np.array([vals[np.where((src == k) & ok)[0][0]]
                                    for k, ok in zip(m, np.ones(len(m), bool))])
                          .shape, vm.shape)  # shape-level check
    # pair consistency: every (key, value) pair in the output existed in input
    pairs_in = set(zip(src.tolist(), vals.tolist()))
    assert all((k, v) in pairs_in for k, v in zip(m.tolist(), vm.tolist()))


def test_multiway_merge_with_values(rng):
    runs = np.sort(rng.integers(0, 2**16, (4, 64)).astype(np.uint32), axis=1)
    vals = np.arange(4 * 64, dtype=np.int32).reshape(4, 64)
    order = np.argsort(runs, axis=1, kind="stable")
    vals = np.take_along_axis(vals, order, axis=1)   # consistent with sorted runs
    m, vm = multiway_merge(jnp.asarray(runs), jnp.asarray(vals))
    assert np.array_equal(np.sort(runs.reshape(-1)), np.asarray(m))
    pairs_in = set(zip(runs.reshape(-1).tolist(), vals.reshape(-1).tolist()))
    assert all((k, v) in pairs_in
               for k, v in zip(np.asarray(m).tolist(), np.asarray(vm).tolist()))


def test_hybrid_zipf_distribution(rng):
    """Paper §6.2 compares on Zipfian keys (the PARADIS benchmark)."""
    from repro.data.distributions import zipf_keys
    x = zipf_keys(rng, 20000, a=1.2)
    out, stats = hybrid_sort(jnp.asarray(x), cfg=TCFG, return_stats=True)
    assert np.array_equal(np.sort(x), np.asarray(out))
    # zipf mass concentrates at tiny keys: heavily skewed -> more passes
    assert int(stats.counting_passes) >= 1
