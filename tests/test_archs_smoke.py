"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step + one decode step on CPU; asserts shapes and no NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, shapes_for
from repro.models import init_params, loss_fn, decode_step, init_cache

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    b, s = 2, 32
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if cfg.frontend == "vision_patches":
        batch["patches"] = jax.random.normal(KEY, (b, cfg.num_patches, cfg.d_model))

    loss, metrics = jax.jit(lambda p, bt: loss_fn(p, cfg, bt))(params, batch)
    assert np.isfinite(float(loss)), arch
    grads = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    b = 2
    cache = init_cache(cfg, b, 16)
    tok = jax.random.randint(KEY, (b, 1), 0, cfg.vocab)
    logits, cache = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))(
        params, tok, cache)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert int(cache.length) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_exact(arch):
    """The full (dry-run-only) configs carry the exact published numbers."""
    cfg = get_config(arch)
    spec = {
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936, 128, 8),
        "kimi_k2_1t_a32b": (61, 7168, 64, 8, 2048, 163840, 384, 8),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048, 0, 0),
        "internlm2_1_8b": (24, 2048, 16, 8, 8192, 92544, 0, 0),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400, 0, 0),
        "phi4_mini_3_8b": (32, 3072, 24, 8, 8192, 200064, 0, 0),
        "deepseek_7b": (30, 4096, 32, 32, 11008, 102400, 0, 0),
        "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001, 0, 0),
        "mamba2_1_3b": (48, 2048, 0, 0, 0, 50280, 0, 0),
        "internvl2_26b": (48, 6144, 48, 8, 16384, 92553, 0, 0),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab, cfg.num_experts, cfg.top_k)
    assert got == spec
    if arch == "mamba2_1_3b":
        assert cfg.ssm_state == 128
    if arch == "hymba_1_5b":
        assert cfg.ssm_state == 16 and cfg.supports_long_context
    # long_500k applies only to sub-quadratic archs
    names = [s.name for s in shapes_for(cfg)]
    if arch in ("mamba2_1_3b", "hymba_1_5b"):
        assert "long_500k" in names
    else:
        assert "long_500k" not in names


def test_param_counts_roughly_match_billing():
    """Sanity: config param math lands near the advertised model sizes."""
    expect = {"kimi_k2_1t_a32b": (0.9e12, 1.2e12),
              "deepseek_67b": (60e9, 72e9),
              "deepseek_7b": (6e9, 8e9),
              "qwen3_moe_30b_a3b": (28e9, 33e9),
              "mamba2_1_3b": (1.1e9, 1.6e9),
              "phi4_mini_3_8b": (3.4e9, 4.6e9),
              "internlm2_1_8b": (1.6e9, 2.2e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
