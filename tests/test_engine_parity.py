"""Engine parity: ``kernel`` ≡ ``argsort`` ≡ ``scan`` ≡ ``np.sort``.

The hybrid sort's three partition engines must produce *byte-identical*
output — keys and values — on every input, and the kernel engine's traced
HLO must be free of comparison sorts (the structural property that separates
the paper's O(n·k/d) pipeline from argsort-based GPU sorts).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ENGINES, SortConfig, hybrid_sort, lsd_sort, resolve_engine
from repro.utils import hlo
from conftest import entropy_keys

# small thresholds so counting passes, merging and the local sort all fire
TCFG = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)
# d=5 leaves a partial-width (2-bit) last pass for 32-bit keys
PCFG = SortConfig(d=5, kpb=32, local_threshold=16, merge_threshold=8)

JNP_ENGINES = ("argsort", "scan")


def _keys(rng, dtype, n):
    if dtype == np.float32:
        x = (rng.standard_normal(n) * 1e3).astype(dtype)
        if n >= 8:
            x[:4] = [0.0, -0.0, np.inf, -np.inf]
        return x
    info = np.iinfo(dtype)
    return rng.integers(info.min, info.max, n, endpoint=True).astype(dtype)


def _all_engine_outputs(x, cfg, values=None):
    outs = {}
    for eng in ENGINES:
        if values is None:
            outs[eng] = (np.asarray(hybrid_sort(jnp.asarray(x), cfg=cfg,
                                                engine=eng)), None)
        else:
            k, v = hybrid_sort(jnp.asarray(x), jnp.asarray(values), cfg=cfg,
                               engine=eng)
            outs[eng] = (np.asarray(k), np.asarray(v))
    return outs


@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32])
@pytest.mark.parametrize(
    "n", [0, 1, 2, 257, pytest.param(4096, marks=pytest.mark.slow)])
def test_parity_keys(rng, dtype, n):
    x = _keys(rng, dtype, n)
    outs = _all_engine_outputs(x, TCFG)
    for eng, (k, _) in outs.items():
        assert np.array_equal(k, np.sort(x)), eng
        assert k.tobytes() == outs["argsort"][0].tobytes(), eng


@pytest.mark.slow
def test_parity_uint64(rng):
    from jax.experimental import enable_x64
    with enable_x64():
        x = rng.integers(0, 2**64, 3000, dtype=np.uint64)
        outs = _all_engine_outputs(x, TCFG)
        for eng, (k, _) in outs.items():
            assert np.array_equal(k, np.sort(x)), eng
            assert k.tobytes() == outs["argsort"][0].tobytes(), eng


@pytest.mark.parametrize("cfg", [TCFG, PCFG], ids=["d8", "d5-partial"])
def test_parity_pairs_byte_identical(rng, cfg):
    """Key-value pairs: every engine applies the exact same permutation."""
    x = entropy_keys(rng, 5000, 2)
    v = np.arange(5000, dtype=np.int32)
    outs = _all_engine_outputs(x, cfg, values=v)
    ka, va = outs["argsort"]
    assert np.array_equal(ka, np.sort(x))
    assert np.array_equal(x[va], ka)                   # pair consistency
    for eng in ENGINES:
        k, v_ = outs[eng]
        assert k.tobytes() == ka.tobytes(), eng
        assert v_.tobytes() == va.tobytes(), eng


def test_parity_value_pytree(rng):
    x = _keys(rng, np.uint32, 1500)
    vals = {"a": jnp.arange(1500, dtype=jnp.int32),
            "b": jnp.arange(1500, dtype=jnp.float32) * 2}
    ka, va = hybrid_sort(jnp.asarray(x), vals, cfg=TCFG, engine="argsort")
    kk, vk = hybrid_sort(jnp.asarray(x), vals, cfg=TCFG, engine="kernel")
    assert np.array_equal(np.asarray(ka), np.asarray(kk))
    for leaf in ("a", "b"):
        assert np.array_equal(np.asarray(va[leaf]), np.asarray(vk[leaf])), leaf


@pytest.mark.parametrize(
    "ands", [0, pytest.param(1, marks=pytest.mark.slow),
             pytest.param(3, marks=pytest.mark.slow), 8])
def test_parity_entropy_sweep(rng, ands):
    """Thearling & Smith reduced-entropy inputs (paper §6's distributions)."""
    x = entropy_keys(rng, 8192, ands)
    outs = _all_engine_outputs(x, TCFG)
    for eng, (k, _) in outs.items():
        assert np.array_equal(k, np.sort(x)), eng


def test_parity_all_equal_and_sentinel(rng):
    for x in (np.full(3000, 0xDEADBEEF, np.uint32),
              np.full(300, 0xFFFFFFFF, np.uint32),
              np.where(rng.random(4000) < 0.3, 0xFFFFFFFF,
                       rng.integers(0, 2**32, 4000)).astype(np.uint32)):
        outs = _all_engine_outputs(x, TCFG)
        for eng, (k, _) in outs.items():
            assert np.array_equal(k, np.sort(x)), eng


def test_parity_stats(rng):
    """Pass counts, segment structure and local-sort usage agree: the engines
    run the *same algorithm*, not merely equivalent sorts."""
    x = entropy_keys(rng, 20000, 1)
    ref = None
    for eng in ENGINES:
        _, stats = hybrid_sort(jnp.asarray(x), cfg=TCFG, return_stats=True,
                               engine=eng)
        got = tuple(int(s) for s in stats)
        ref = ref or got
        assert got == ref, eng


def test_lsd_engine_parity(rng):
    x = rng.integers(0, 2**32, 4000, dtype=np.uint32)
    v = np.arange(4000, dtype=np.int32)
    ref_k, ref_v = None, None
    for eng in ENGINES:
        k, v_ = lsd_sort(jnp.asarray(x), jnp.asarray(v), d=8, engine=eng,
                         kpb=512)
        k, v_ = np.asarray(k), np.asarray(v_)
        assert np.array_equal(k, np.sort(x)), eng
        if ref_k is None:
            ref_k, ref_v = k, v_
        # LSD is stable in every engine, so values are byte-identical too
        assert np.array_equal(v_, ref_v), eng


def test_parity_truncated_max_passes(rng):
    """Under max_passes truncation every engine returns the same partial
    result: partition-ordered, with only done buckets finished."""
    x = rng.integers(0, 2**32, 4000, dtype=np.uint32)
    x[:2000] &= 0x00FFFFFF                   # half the keys share top byte 0
    outs = [np.asarray(hybrid_sort(jnp.asarray(x), cfg=TCFG, engine=e,
                                   max_passes=1)) for e in ENGINES]
    for eng, o in zip(ENGINES, outs):
        assert o.tobytes() == outs[0].tobytes(), eng
    # one pass cannot fully sort the giant shared-prefix bucket
    assert not np.array_equal(outs[0], np.sort(x))
    assert np.array_equal(np.sort(outs[0]), np.sort(x))


def test_cfg_rank_engine_is_honoured(rng):
    """SortConfig.rank_engine is the default when no engine= is passed."""
    x = rng.integers(0, 2**32, 2000, dtype=np.uint32)
    cfg = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32,
                     rank_engine="kernel")
    f = jax.jit(lambda a: hybrid_sort(a, cfg=cfg))
    assert hlo.sort_op_count(f.lower(jnp.asarray(x)).as_text()) == 0
    assert np.array_equal(np.asarray(f(jnp.asarray(x))), np.sort(x))


def test_resolve_engine():
    assert resolve_engine("kernel") == "kernel"
    assert resolve_engine(None) in ENGINES
    assert resolve_engine("auto") == resolve_engine(None)
    with pytest.raises(ValueError):
        resolve_engine("bogosort")


# --------------------- HLO structure (acceptance gate) ----------------------

def test_kernel_engine_hlo_is_sort_free():
    """engine="kernel" must trace to zero (stable)HLO sort ops — the whole
    point of the pipeline; argsort is the positive control."""
    x = jnp.zeros(4096, jnp.uint32)
    f = lambda eng: jax.jit(
        lambda a: hybrid_sort(a, cfg=TCFG, engine=eng)).lower(x).as_text()
    assert hlo.sort_op_count(f("kernel")) == 0
    assert hlo.sort_op_count(f("argsort")) > 0


def test_kernel_engine_hlo_sort_free_with_values_and_stats():
    x = jnp.zeros(2048, jnp.uint32)
    v = jnp.zeros(2048, jnp.int32)
    txt = jax.jit(lambda a, b: hybrid_sort(
        a, b, cfg=TCFG, engine="kernel", return_stats=True)).lower(x, v).as_text()
    assert hlo.sort_op_count(txt) == 0


def test_lsd_kernel_engine_hlo_is_sort_free():
    x = jnp.zeros(2048, jnp.uint32)
    txt = jax.jit(lambda a: lsd_sort(a, d=8, engine="kernel",
                                     kpb=512)).lower(x).as_text()
    assert hlo.sort_op_count(txt) == 0
