"""Property tests: the fused kernel engine is byte-identical to argsort.

Hypothesis (optional test dependency, as in test_core_sort) drives random
(dtype, size, entropy, payload) combinations through ``hybrid_sort`` with
``engine="kernel"`` (the fused single-launch pipeline) and ``engine="argsort"``
and requires byte-identical keys AND values.  A deterministic sweep below
covers the same grid — including empty and all-equal inputs — so the
invariant is exercised even where hypothesis is not installed.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:  # hypothesis is an optional test dependency (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False

from repro.core import SortConfig, hybrid_sort
from conftest import entropy_keys

# small thresholds so counting passes, merging and the local sort all fire
TCFG = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)

DTYPES = (np.uint32, np.int32, np.float32)


def _keys(rng, dtype, n, ands):
    if dtype == np.float32:
        x = (rng.standard_normal(n) * 10.0 ** rng.integers(0, 6)).astype(dtype)
        if n >= 8:
            x[:4] = [0.0, -0.0, np.inf, -np.inf]
        return x
    x = entropy_keys(rng, n, ands, dtype=np.uint32)
    return x.astype(dtype)


def _assert_fused_matches_argsort(x, with_values):
    v = np.arange(x.shape[0], dtype=np.int32) if with_values else None
    if v is None:
        ka = hybrid_sort(jnp.asarray(x), cfg=TCFG, engine="argsort")
        kk = hybrid_sort(jnp.asarray(x), cfg=TCFG, engine="kernel")
        va = vk = None
    else:
        ka, va = hybrid_sort(jnp.asarray(x), jnp.asarray(v), cfg=TCFG,
                             engine="argsort")
        kk, vk = hybrid_sort(jnp.asarray(x), jnp.asarray(v), cfg=TCFG,
                             engine="kernel")
    ka, kk = np.asarray(ka), np.asarray(kk)
    assert np.array_equal(ka, np.sort(x)), "argsort oracle broken"
    assert ka.tobytes() == kk.tobytes(), "fused keys diverge"
    if v is not None:
        assert np.asarray(va).tobytes() == np.asarray(vk).tobytes(), \
            "fused values diverge"


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**32 - 1),
           st.sampled_from(DTYPES),
           st.integers(0, 500),
           st.integers(0, 8),
           st.booleans())
    def test_fused_matches_argsort_property(seed, dtype, n, ands, with_values):
        rng = np.random.default_rng(seed)
        _assert_fused_matches_argsort(_keys(rng, dtype, n, ands), with_values)

    @pytest.mark.slow
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(0, 400), st.integers(0, 4))
    def test_fused_matches_argsort_property_uint64(seed, n, ands):
        from jax.experimental import enable_x64
        rng = np.random.default_rng(seed)
        x = entropy_keys(rng, n, ands, dtype=np.uint64)
        with enable_x64():
            _assert_fused_matches_argsort(x, with_values=False)


# ------- deterministic sweep: runs with or without hypothesis ---------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize(
    "n", [0, 1, 2, 63, 64, 65, pytest.param(257, marks=pytest.mark.slow)])
@pytest.mark.parametrize("with_values", [False, True])
def test_fused_matches_argsort_sweep(rng, dtype, n, with_values):
    _assert_fused_matches_argsort(_keys(rng, dtype, n, 1), with_values)


@pytest.mark.parametrize("ands", [0, 3, 8, 30])
def test_fused_matches_argsort_entropy(rng, ands):
    _assert_fused_matches_argsort(entropy_keys(rng, 3000, ands), True)


def test_fused_matches_argsort_all_equal_and_sentinel(rng):
    for x in (np.zeros(1000, np.uint32),
              np.full(1000, 0xFFFFFFFF, np.uint32),     # == pad sentinel
              np.full(257, 0xDEADBEEF, np.uint32)):
        _assert_fused_matches_argsort(x, True)


def test_fused_matches_argsort_uint64(rng):
    from jax.experimental import enable_x64
    x = entropy_keys(rng, 2000, 2, dtype=np.uint64)
    with enable_x64():
        _assert_fused_matches_argsort(x, with_values=False)