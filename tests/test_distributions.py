"""Deterministic-replay contract of the key-distribution generators.

The generators thread an explicit PRNG (int seed or ``Generator``) + dtype;
a seed must replay bit-identically in isolation — independent of call order
— and must never touch the global ``np.random`` state (the silent
seed-reuse bug between bench runs this replaces).
"""
import numpy as np
import pytest

from repro.data.distributions import (as_generator, clustered_keys,
                                      constant_keys, entropy_keys, zipf_keys)

GENS = [
    lambda rng: entropy_keys(rng, 257, 2),
    lambda rng: entropy_keys(rng, 257, 0, dtype=np.uint64),
    lambda rng: zipf_keys(rng, 257, a=1.3),
    lambda rng: clustered_keys(rng, 257, clusters=8, spread=1 << 8),
    lambda rng: clustered_keys(rng, 257, dtype=np.uint64),
]


@pytest.mark.parametrize("gen", GENS)
def test_seed_replays_bit_identically(gen):
    a, b = gen(7), gen(7)
    assert a.tobytes() == b.tobytes()
    assert a.tobytes() != gen(8).tobytes()


@pytest.mark.parametrize("gen", GENS)
def test_replay_is_call_order_independent(gen):
    ref = gen(3)
    for other in GENS:                       # interleave arbitrary draws
        other(11)
    assert gen(3).tobytes() == ref.tobytes()


def test_global_numpy_state_untouched():
    np.random.seed(123)
    before = np.random.get_state()[1].tobytes()
    for gen in GENS:
        gen(5)
    assert np.random.get_state()[1].tobytes() == before


def test_shared_generator_advances():
    rng = np.random.default_rng(0)
    assert entropy_keys(rng, 64, 1).tobytes() != \
        entropy_keys(rng, 64, 1).tobytes()


def test_as_generator_rejects_implicit_state():
    assert isinstance(as_generator(5), np.random.Generator)
    g = np.random.default_rng(1)
    assert as_generator(g) is g
    with pytest.raises(TypeError):
        as_generator(None)
    with pytest.raises(TypeError):
        entropy_keys("0", 8, 0)


def test_dtype_threading():
    assert entropy_keys(1, 16, 0, dtype=np.uint64).dtype == np.uint64
    assert zipf_keys(1, 16, dtype=np.uint64).dtype == np.uint64
    assert clustered_keys(1, 16, dtype=np.uint16).dtype == np.uint16
    assert constant_keys(4, 7, dtype=np.uint8).dtype == np.uint8


def test_clustered_keys_are_clustered():
    x = clustered_keys(0, 5000, clusters=4, spread=1 << 8)
    # 4 clusters of width 256: high bytes collapse to ~4 distinct values
    assert len(np.unique(x >> np.uint32(16))) <= 8


def test_clustered_keys_uint64_offsets_not_quantised():
    # a float64 intermediate would round 64-bit keys to 53-bit mantissas,
    # collapsing the uniform [0, spread) offsets to a few low-bit patterns
    x = clustered_keys(0, 100_000, clusters=4, spread=1 << 16,
                       dtype=np.uint64)
    assert len(np.unique(x & np.uint64(0x7FF))) > 1500
