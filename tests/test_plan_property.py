"""Property tests for ``core.plan`` — the invariants every engine leans on.

PR 2 made ``core.plan`` the single partition-plan layer under the hybrid/LSD
drivers, MoE dispatch and the data pipeline, but its invariants were only
tested *indirectly* (via engine parity).  The out-of-core driver now also
trusts them across launch boundaries, so they get their own wall:

  * ``make_region_blocks``: the block descriptors cover every key position
    exactly once — active segments are partitioned (each by its own blocks,
    exactly once, with carry resets on region starts), the done gaps between
    them are skipped by the partition and covered by copy-through blocks,
  * ``next_active_table``: the compact-row map is a bijection from the > ∂̂
    sub-buckets onto the next pass's active rows 0..m-1, in position order,
  * ``merge_rows`` (R3): zero sub-buckets never open a group, > ∂̂
    sub-buckets always stand alone, merged groups stay below ∂, and the
    merged-group destination offsets are monotone.

Hypothesis drives random states when available; a deterministic sweep covers
the same ground on bare interpreters (the repo-wide guard idiom).
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:  # hypothesis is an optional test dependency (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False

from repro.core import plan


# --------------------------- state generators -------------------------------

def random_bucket_state(rng, n, max_segments):
    """Dense (seg_id, done) per-key state as the drivers carry it: segment
    ids non-decreasing from 0, done constant within a segment."""
    nseg = int(rng.integers(1, max_segments + 1))
    cuts = np.sort(rng.choice(np.arange(1, n), size=min(nseg - 1, n - 1),
                              replace=False)) if n > 1 else np.array([], int)
    seg_id = np.zeros(n, np.int32)
    seg_id[cuts] = 1
    seg_id = np.cumsum(seg_id).astype(np.int32)
    seg_done = rng.random(seg_id[-1] + 1) < 0.5 if n else np.array([], bool)
    done = seg_done[seg_id] if n else np.zeros(0, bool)
    return seg_id, done


def check_region_blocks(seg_id, done, kpb):
    """Assert the full coverage contract of ``make_region_blocks``."""
    n = seg_id.shape[0]
    nseg = int(seg_id[-1]) + 1 if n else 0
    a_max = max(1, nseg)
    asegs = plan.active_segments(jnp.asarray(seg_id), jnp.asarray(done),
                                 a_max)
    g_max = plan.max_region_blocks(n, kpb, a_max)
    blocks = plan.make_region_blocks(asegs.base, asegs.size, n, kpb, g_max)
    seg, off, reset, cnt, act = (np.asarray(x) for x in blocks)

    assert seg.shape == (g_max,)
    assert int(cnt.sum()) == n

    # exactly-once coverage of every key position
    cover = np.zeros(n, np.int32)
    for o, c in zip(off, cnt):
        assert c >= 0 and (c == 0 or (0 <= o and o + c <= n))
        cover[o:o + c] += 1
    assert (cover == 1).all()

    # partition blocks cover exactly the active keys of their own segment;
    # copy-through blocks cover exactly the done gaps (gap-skipping)
    base = np.asarray(asegs.base)
    size = np.asarray(asegs.size)
    for s, o, c, a in zip(seg, off, cnt, act):
        if c == 0:
            continue
        if a == 1:
            assert s < a_max
            assert base[s] <= o and o + c <= base[s] + size[s]
            assert not done[o:o + c].any()
            assert (seg_id[o:o + c] == seg_id[o]).all()
        else:
            assert s == a_max
            assert done[o:o + c].all()

    # per active segment: its blocks tile [base, base+size) in order, carry
    # reset set on the first block only
    for i in range(a_max):
        if size[i] == 0:
            continue
        mine = [(o, c, r) for s, o, c, r, a in
                zip(seg, off, cnt, reset, act) if a == 1 and s == i and c]
        mine.sort()
        expect = int(base[i])
        for j, (o, c, r) in enumerate(mine):
            assert o == expect
            assert r == (1 if j == 0 else 0)
            expect += c
        assert expect == int(base[i]) + int(size[i])


def replay_merge_rows(row, local_threshold, merge_threshold):
    """Straight-line numpy oracle of the R3 scan in ``plan.merge_rows``."""
    acc = merge_threshold
    gstart, gdone = [], []
    for s in row:
        big = s > local_threshold
        extend = (s == 0) or ((not big) and (acc + s < merge_threshold))
        acc = acc + s if extend else (merge_threshold if big else s)
        gstart.append(not extend)
        gdone.append(not big)
    return np.array(gstart), np.array(gdone)


def check_merge_rows(hist, local_threshold, merge_threshold):
    gstart, gdone = (np.asarray(x) for x in plan.merge_rows(
        jnp.asarray(hist), local_threshold, merge_threshold))
    for row, gs, gd in zip(hist, gstart, gdone):
        ref_gs, ref_gd = replay_merge_rows(row, local_threshold,
                                           merge_threshold)
        assert (gs == ref_gs).all() and (gd == ref_gd).all()

        # R3 invariants
        assert not gs[row == 0].any()                # zeros never open groups
        big = row > local_threshold
        assert gs[big].all()                         # big buckets stand alone
        assert not gd[big].any()
        nz = row > 0
        if nz.any():
            assert gs[np.argmax(nz)]                 # first nonzero opens one
        # merged groups (>= 2 nonzero members) stay below ∂ and are done
        gid = np.cumsum(gs) - 1
        excl = np.cumsum(row) - row                  # destination offsets
        group_offsets = []
        for g in range(gid.max() + 1 if len(row) else 0):
            members = nz & (gid == g)
            if members.sum() >= 2:
                assert row[members].sum() < merge_threshold
                assert gd[members].all()
            if members.any():
                group_offsets.append(excl[np.argmax(members)])
        # merged R3 offsets are monotone (strictly: every group is nonempty)
        assert (np.diff(group_offsets) > 0).all()


def check_next_active_table(hist, local_threshold):
    a_max = hist.shape[0]
    table = np.asarray(plan.next_active_table(jnp.asarray(hist),
                                              local_threshold, a_max))
    mask = (hist > local_threshold).reshape(-1)
    # bijection onto 0..m-1 in position order over the active rows...
    assert (table[mask] == np.arange(mask.sum())).all()
    # ...and the sentinel a_max everywhere else
    assert (table[~mask] == a_max).all()


# --------------------------- hypothesis drivers -----------------------------

if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(1, 300),
           st.sampled_from([8, 16, 64]), st.integers(1, 12))
    def test_region_blocks_property(seed, n, kpb, max_segments):
        rng = np.random.default_rng(seed)
        seg_id, done = random_bucket_state(rng, n, max_segments)
        check_region_blocks(seg_id, done, kpb)

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(1, 6),
           st.sampled_from([4, 16]), st.integers(2, 40))
    def test_merge_rows_property(seed, a, r, local_threshold):
        rng = np.random.default_rng(seed)
        merge_threshold = int(rng.integers(1, local_threshold + 1))
        hist = rng.integers(0, 3 * local_threshold, (a, r)).astype(np.int32)
        hist[rng.random((a, r)) < 0.4] = 0
        check_merge_rows(hist, local_threshold, merge_threshold)

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(1, 8),
           st.sampled_from([4, 16]), st.integers(1, 40))
    def test_next_active_table_property(seed, a, r, local_threshold):
        rng = np.random.default_rng(seed)
        hist = rng.integers(0, 3 * local_threshold, (a, r)).astype(np.int32)
        check_next_active_table(hist, local_threshold)


# ------- deterministic sweep: runs with or without hypothesis ---------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("kpb", [8, 64])
def test_region_blocks_sweep(seed, kpb):
    rng = np.random.default_rng(seed)
    for n in (1, 2, kpb - 1, kpb, kpb + 1, 257):
        seg_id, done = random_bucket_state(rng, n, 8)
        check_region_blocks(seg_id, done, kpb)


def test_region_blocks_all_done_and_all_active(rng):
    n = 100
    check_region_blocks(np.zeros(n, np.int32), np.ones(n, bool), 16)
    check_region_blocks(np.zeros(n, np.int32), np.zeros(n, bool), 16)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_merge_rows_sweep(seed):
    rng = np.random.default_rng(seed)
    hist = rng.integers(0, 96, (4, 16)).astype(np.int32)
    hist[rng.random((4, 16)) < 0.4] = 0
    check_merge_rows(hist, 32, 24)
    check_merge_rows(np.zeros((2, 8), np.int32), 32, 24)
    check_merge_rows(np.full((1, 8), 100, np.int32), 32, 24)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_next_active_table_sweep(seed):
    rng = np.random.default_rng(seed)
    check_next_active_table(rng.integers(0, 96, (6, 16)).astype(np.int32), 32)
    check_next_active_table(np.zeros((3, 4), np.int32), 32)
    check_next_active_table(np.full((3, 4), 100, np.int32), 32)


# --------------------------- packed super-steps ------------------------------

def check_pack_region_blocks(seg_id, done, kpb, batch):
    """Packing contract: flattening the (G', B) tables gives the flat rows
    back in order, the tail pads with inert rows, and nothing about the
    coverage story changes."""
    n = seg_id.shape[0]
    nseg = int(seg_id[-1]) + 1 if n else 0
    a_max = max(1, nseg)
    asegs = plan.active_segments(jnp.asarray(seg_id), jnp.asarray(done),
                                 a_max)
    g_max = plan.max_region_blocks(n, kpb, a_max)
    flat = plan.make_region_blocks(asegs.base, asegs.size, n, kpb, g_max)
    packed = plan.pack_region_blocks(flat, batch, seg_pad=a_max)
    g_steps = -(-g_max // batch)
    for name, f, p in zip(flat._fields, flat, packed):
        p = np.asarray(p)
        f = np.asarray(f)
        assert p.shape == (g_steps, batch), name
        # row order preserved exactly — the carry-chain compatibility rule
        assert np.array_equal(p.reshape(-1)[:g_max], f), name
    # the padded tail is inert: no live lanes, copy-through, carry-reset
    tail = {name: np.asarray(p).reshape(-1)[g_max:]
            for name, p in zip(packed._fields, packed)}
    assert (tail["count"] == 0).all()
    assert (tail["active"] == 0).all()
    assert (tail["reset"] == 1).all()
    assert (tail["seg"] == a_max).all()     # flat table's pad convention
    # batch routing through make_region_blocks is the same packing
    direct = plan.make_region_blocks(asegs.base, asegs.size, n, kpb, g_max,
                                     batch=batch)
    for a, b in zip(direct, packed):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("batch", [1, 3, 8])
def test_pack_region_blocks_deterministic(batch):
    rng = np.random.default_rng(7)
    for n, kpb in [(1, 8), (100, 16), (1000, 64), (4096, 256)]:
        seg_id, done = random_bucket_state(rng, n, max_segments=9)
        check_pack_region_blocks(seg_id, done, kpb, batch)


def test_pack_region_blocks_rejects_bad_batch():
    blocks = plan.make_region_blocks(jnp.zeros((1,), jnp.int32),
                                     jnp.full((1,), 64, jnp.int32), 64, 16,
                                     plan.max_region_blocks(64, 16, 1))
    with pytest.raises(ValueError, match="batch"):
        plan.pack_region_blocks(blocks, 0)
