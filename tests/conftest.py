"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; multi-device tests spawn subprocesses."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def entropy_keys(rng, n, ands, dtype=np.uint32):
    """Thearling & Smith entropy-reduction benchmark (paper §6): AND together
    1 + ands uniform draws; ands=0 -> uniform, more ANDs -> lower entropy."""
    info = np.iinfo(dtype)
    x = rng.integers(0, info.max, n, dtype=dtype, endpoint=True)
    for _ in range(ands):
        x &= rng.integers(0, info.max, n, dtype=dtype, endpoint=True)
    return x
