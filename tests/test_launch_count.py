"""Launch-census regression tests: the fused engine's structural guarantee.

The point of ``kernels.fused`` is that one counting pass is ONE Pallas
launch (§4.3–§4.4: partition + scatter + next-pass histogram fused), so the
whole hybrid sort traces to a fixed set of launch sites — the prologue
histogram, the per-pass fused launch inside the while loop, and one bitonic
local-sort launch per size class (``core.hybrid.local_sort_classes``) —
independent of the data and the executed pass count.  Batched grid steps
(``plan.pack_region_blocks``) shrink the fused launch's *grid* from g_max
to ⌈g_max/B⌉ but must not change the launch count: the while body stays
exactly one ``pallas_call``.  ``utils.hlo`` counts ``pallas_call`` sites in
the jaxpr and reads their grids (interpret mode has no custom-call in the
lowered HLO; on hardware ``pallas_custom_call_count`` covers the text).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts as an
from repro.core import SortConfig, hybrid_sort, lsd_sort, model, plan
from repro.core.outofcore import _sort_chunk, merge_round
from repro.core.segmented import counting_partition
from repro.kernels import merge as kmerge
from repro.kernels.fused import pad_length
from repro.utils import hlo

TCFG = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)


def _hybrid_launches(n, cfg):
    """Prologue + fused pass + one bitonic launch per local-sort class —
    read from the registered contract's symbolic formula, so the test and
    the analyzer verify the SAME declaration."""
    return an.expected_census("hybrid_sort", an.hybrid_params(n, cfg))["total"]


def test_hybrid_fused_engine_one_launch_per_pass():
    """THE acceptance gate: the counting-pass loop body contains exactly one
    pallas_call, and the whole trace exactly prologue + pass + the static
    local-sort classes, for any input size."""
    for n in (257, 4096, 20000):
        jx = jax.make_jaxpr(
            lambda a: hybrid_sort(a, cfg=TCFG, engine="kernel"))(
                jnp.zeros(n, jnp.uint32))
        assert hlo.while_body_pallas_launches(jx) == [1], n
        assert hlo.pallas_launch_count(jx) == _hybrid_launches(n, TCFG), n


def test_hybrid_fused_launches_with_values_and_stats():
    x = jnp.zeros(2048, jnp.uint32)
    v = {"a": jnp.zeros(2048, jnp.int32), "b": jnp.zeros(2048, jnp.float32)}
    jx = jax.make_jaxpr(lambda a, b: hybrid_sort(
        a, b, cfg=TCFG, engine="kernel", return_stats=True))(x, v)
    assert hlo.while_body_pallas_launches(jx) == [1]
    assert hlo.pallas_launch_count(jx) == _hybrid_launches(2048, TCFG)


def test_hybrid_batched_grid_steps_shrink_the_grid():
    """Packing B descriptor rows per super-step divides the fused launch's
    grid by B (⌈g_max/B⌉) while the launch census is unchanged — the
    batched-step contract of plan.pack_region_blocks."""
    n = 4096
    x = jnp.zeros(n, jnp.uint32)
    for b in (1, 4, 16):
        cfg = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32,
                         step_batch=b)
        a_max = model.max_active_buckets(n, cfg)
        g_max = plan.max_region_blocks(n, cfg.kpb, a_max)
        jx = jax.make_jaxpr(
            lambda a: hybrid_sort(a, cfg=cfg, engine="kernel"))(x)
        assert hlo.while_body_pallas_launches(jx) == [1], b
        assert hlo.pallas_launch_count(jx) == _hybrid_launches(n, cfg), b
        grids = hlo.pallas_grid_sizes(jx)
        # trace order: prologue histogram, fused pass (while body), classes
        assert grids[1] == (-(-g_max // b),), (b, grids)
        assert len(grids) == _hybrid_launches(n, cfg), b


def test_lsd_fused_engine_launch_count():
    """LSD unrolls: ⌈k/d⌉ fused launches + the single prologue histogram,
    each pass on the batched ⌈g_max/B⌉ grid."""
    x = jnp.zeros(2048, jnp.uint32)
    for d in (8, 5):
        jx = jax.make_jaxpr(
            lambda a: lsd_sort(a, d=d, engine="kernel", kpb=512,
                               step_batch=4))(x)
        want = an.expected_census("lsd_sort", an.lsd_params(2048, d, 512, 4))
        assert hlo.pallas_launch_count(jx) == want["total"], d
        g_max = plan.max_region_blocks(2048, 512, 1)
        assert all(g == (-(-g_max // 4),)
                   for g in hlo.pallas_grid_sizes(jx)[1:]), d


def test_counting_partition_fused_launch_count():
    """One standalone partition = prologue histogram + one fused launch."""
    ids = jnp.zeros(1000, jnp.int32)
    jx = jax.make_jaxpr(
        lambda i: counting_partition(i, 8, engine="kernel"))(ids)
    want = an.expected_census("single_pass_partition", an.spp_params(1000, 8))
    assert hlo.pallas_launch_count(jx) == want["total"]


def test_jnp_engines_launch_free():
    x = jnp.zeros(4096, jnp.uint32)
    for eng in ("argsort", "scan"):
        jx = jax.make_jaxpr(lambda a: hybrid_sort(a, cfg=TCFG, engine=eng))(x)
        assert hlo.pallas_launch_count(jx) == 0, eng


def test_ooc_merge_one_launch_per_round():
    """§5 census: EVERY merge round of an out-of-core sort — whatever the
    run-length mix, group width, or leftover single-run group — is exactly
    one pallas_call, so a full merge phase is ⌈log_K(runs)⌉ launches."""
    tile = 64
    lens = [256] * 8                       # 8 runs, kway=4 -> rounds of 2, 1
    kway = 4
    rounds = 0
    while len(lens) > 1:
        n = sum(lens)
        ck = jnp.zeros((pad_length(n, tile),), jnp.uint32)
        jx = jax.make_jaxpr(
            lambda a, b: merge_round(a, (), b, (), lens=tuple(lens),
                                     kway=kway, tile=tile, n=n,
                                     interpret=True))(ck, jnp.zeros_like(ck))
        census = hlo.launch_census(jx)
        assert census["total"] == 1, lens
        assert not any(census["while_bodies"]), lens
        lens = [sum(g) for g in kmerge.merge_groups(lens, kway)]
        rounds += 1
    assert rounds == kmerge.num_merge_rounds(8, kway) == 2


def test_ooc_chunk_sort_keeps_one_launch_per_pass():
    """The PR 2 invariant under the new driver: an oocsort chunk sort on the
    kernel engine still traces to one launch inside the pass loop, prologue
    + fused pass + the local-sort classes in total."""
    total = _hybrid_launches(256, TCFG)
    jx = jax.make_jaxpr(
        lambda a: _sort_chunk(a, (), TCFG, "kernel", True))(
            jnp.zeros(256, jnp.uint32))
    assert hlo.while_body_pallas_launches(jx) == [1]
    assert hlo.pallas_launch_count(jx) == total
    assert hlo.launch_census(jx) == {"total": total, "while_bodies": [1]}


def test_spill_slab_sweep_single_launch_and_sort_free():
    """§5 spill census: one group-slab sweep — the device-side pad of the
    exact strip upload plus the merge-kernel launch — is exactly ONE
    pallas_call with no launches hiding in while bodies, and traces to zero
    (stable)HLO sort ops."""
    slab, tile, kway = 64, 16, 4
    buf = pad_length(slab, tile)
    G = slab // tile
    sentinel = ~jnp.zeros((), jnp.uint32)

    def sweep(up_k, alt_k, off, cnt, ws, wt):
        slab_k = jnp.concatenate(
            [up_k, jnp.full((buf - up_k.shape[0],), sentinel, jnp.uint32)])
        return kmerge.kway_merge_round(slab_k, (), alt_k, (), off, cnt, ws,
                                       wt, kway=kway, tpb=tile, n=slab,
                                       interpret=True)

    args = (jnp.zeros((48,), jnp.uint32), jnp.full((buf,), sentinel),
            jnp.zeros((G,), jnp.int32), jnp.zeros((G,), jnp.int32),
            jnp.full((G * kway,), slab, jnp.int32),
            jnp.zeros((G * kway,), jnp.int32))
    jx = jax.make_jaxpr(sweep)(*args)
    census = hlo.launch_census(jx)
    assert census["total"] == 1
    assert not any(census["while_bodies"])
    assert hlo.sort_op_count(jax.jit(sweep).lower(*args).as_text()) == 0


def test_spill_strip_tables_drive_single_launch(rng):
    """End-to-end slab sweep on real spill_group_plan tables: one launch,
    and the streamed strip output equals the whole-group reference."""
    runs = [np.sort(rng.integers(0, 50, l).astype(np.uint32))
            for l in (100, 37, 23)]
    tile, slab, kway = 16, 32, 4
    buf = pad_length(slab, tile)
    sent = np.uint32(0xFFFFFFFF)
    out = np.empty(sum(len(r) for r in runs), np.uint32)
    sweep = lambda a, b, *t: kmerge.kway_merge_round(
        a, (), b, (), *t, kway=kway, tpb=tile, n=slab, interpret=True)
    for i, strip in enumerate(kmerge.spill_group_plan(runs, kway, tile,
                                                      slab)):
        wins = [runs[r][strip.win_lo[r]:strip.win_lo[r] + strip.win_len[r]]
                for r in range(len(runs))]
        up = np.concatenate(wins + [np.full(buf - strip.out_len, sent)])
        args = (jnp.asarray(up), jnp.full((buf,), sent, jnp.uint32),
                *(jnp.asarray(t) for t in strip.tables))
        if i == 0:                        # census the real-tables sweep too
            census = hlo.launch_census(jax.make_jaxpr(sweep)(*args))
            assert census == {"total": 1, "while_bodies": []}
        ok, _ = sweep(*args)
        out[strip.out_lo:strip.out_lo + strip.out_len] = \
            np.asarray(ok[:strip.out_len])
    flat = np.concatenate(runs)
    rid = np.concatenate([[i] * len(r) for i, r in enumerate(runs)])
    ref = flat[np.lexsort((np.arange(len(flat)), rid, flat))]
    assert np.array_equal(out, ref)


def test_searchsorted_rank_byte_identical_to_counting_rank(rng):
    """The parity gate for the kernel's tile-local merge rework: the
    per-run-pair searchsorted co-rank kernel is byte-identical to the
    (K·T)² counting-rank kernel it replaces, keys and values, including
    sentinel-valued keys and heavy duplicates."""
    lens = (200, 64, 1, 129)
    n = sum(lens)
    tile = 32
    runs = [np.sort(np.where(rng.random(l) < 0.2, 0xFFFFFFFF,
                             rng.integers(0, 12, l)).astype(np.uint32))
            for l in lens]
    flat = np.concatenate(runs)
    n_pad = pad_length(n, tile)
    ck = jnp.asarray(np.concatenate(
        [flat, np.full(n_pad - n, 0xFFFFFFFF, np.uint32)]))
    vals = np.arange(n, dtype=np.int32)
    cv = (jnp.asarray(np.concatenate([vals, np.zeros(n_pad - n, np.int32)])),)
    tables = kmerge.merge_path_partition(ck, lens, 4, tile)
    outs = {}
    for rank in ("searchsorted", "counting"):
        ok, ov = kmerge.kway_merge_round(
            ck, cv, jnp.full_like(ck, 0xFFFFFFFF),
            (jnp.zeros_like(cv[0]),), *tables, kway=4, tpb=tile, n=n,
            interpret=True, rank=rank)
        outs[rank] = (np.asarray(ok).tobytes(), np.asarray(ov[0]).tobytes())
    assert outs["searchsorted"] == outs["counting"]
    with pytest.raises(ValueError, match="rank"):
        kmerge.kway_merge_round(ck, cv, ck, cv, *tables, kway=4, tpb=tile,
                                n=n, rank="bogus")


def _abstract_mesh(n, name):
    """AbstractMesh lets shard_map bodies trace/lower in-process with no
    fake devices, so the exchange census runs in the fast tier."""
    try:
        return jax.sharding.AbstractMesh((n,), (name,))
    except TypeError:                       # older ctor: ((name, size),)
        return jax.sharding.AbstractMesh(((name, n),))


def _dist_launches(n_local, num_chunks, max_attempts, cfg):
    """Launch-site formula for the distributed exchange body:

    per chunk one full hybrid sort (prologue + fused pass + local-sort
    classes), per ATTEMPT SITE per chunk one shard-bucketing counting pass
    (2 sites: prologue + fused), plus the single 2-bucket validity
    compaction pass.  Retry sites are lax.cond-guarded, so sites scale with
    ``max_attempts`` while *executed* launches scale with the attempts
    ledger — same executed-vs-nominal idiom as the adaptive pass elision.
    The formula lives in ``core.distributed.ANALYSIS_CONTRACT``; this reads
    it through the registry so test and analyzer cannot drift.
    """
    params = an.dist_params(8, n_local, num_chunks, max_attempts, cfg)
    return an.expected_census("distributed_shard", params)["total"]


def test_distributed_shard_body_launch_census():
    """ONE pallas_call per counting pass inside the shard_map body — for
    the local chunk sorts (their while bodies stay [1]), every
    cond-guarded exchange attempt's bucketing pass, and the compaction
    pass — at every (chunks, attempts) shape, keys-only and KV."""
    from repro.core.distributed import make_distributed_sort

    mesh = _abstract_mesh(8, "data")
    n_local = 512
    x = jnp.zeros(8 * n_local, jnp.uint32)
    for num_chunks, max_attempts in ((1, 1), (1, 3), (2, 3)):
        fn = make_distributed_sort(mesh, "data", cfg=TCFG, engine="kernel",
                                   num_chunks=num_chunks,
                                   max_attempts=max_attempts)
        census = hlo.launch_census(jax.make_jaxpr(fn)(x))
        expected = _dist_launches(n_local, num_chunks, max_attempts, TCFG)
        assert census["total"] == expected, (num_chunks, max_attempts)
        assert census["while_bodies"] == [1] * num_chunks, num_chunks
    # KV payloads ride as one int32 rank per key: census unchanged
    fn = make_distributed_sort(mesh, "data", cfg=TCFG, engine="kernel",
                               num_chunks=2, max_attempts=3)
    census = hlo.launch_census(
        jax.make_jaxpr(lambda k, v: fn(k, v))(x, jnp.zeros_like(x)))
    assert census["total"] == _dist_launches(n_local, 2, 3, TCFG)
    assert census["while_bodies"] == [1, 1]


def test_distributed_retry_replay_conserves_per_pass_launches():
    """Raising max_attempts adds exactly 2 * num_chunks sites per extra
    cond-guarded attempt (prologue + fused bucketing per chunk) and
    nothing else — the replay re-uses the SAME counting-pass primitive,
    no hidden launches or re-sorts."""
    from repro.core.distributed import make_distributed_sort

    mesh = _abstract_mesh(8, "data")
    n_local, num_chunks = 512, 2
    x = jnp.zeros(8 * n_local, jnp.uint32)
    totals = []
    for max_attempts in (1, 2, 3):
        fn = make_distributed_sort(mesh, "data", cfg=TCFG, engine="kernel",
                                   num_chunks=num_chunks,
                                   max_attempts=max_attempts)
        census = hlo.launch_census(jax.make_jaxpr(fn)(x))
        assert census["while_bodies"] == [1] * num_chunks, max_attempts
        totals.append(census["total"])
    assert np.diff(totals).tolist() == [2 * num_chunks] * 2


def test_distributed_kernel_engine_sort_free():
    """Zero (stable)HLO sort ops in the whole lowered exchange under
    engine="kernel": local sorts are hybrid, splitter selection merges
    sorted samples (all_gather keeps rows intact), bucketing is the fused
    counting pass, the finish is searchsorted-based multiway merge +
    compaction.  The argsort engine keeps its sorts — the gate measures
    the kernel path, not the lowering."""
    from repro.core.distributed import make_distributed_sort

    mesh = _abstract_mesh(8, "data")
    x = jnp.zeros(8 * 512, jnp.uint32)
    fn = make_distributed_sort(mesh, "data", cfg=TCFG, engine="kernel",
                               num_chunks=2, max_attempts=2)
    assert hlo.sort_op_count(jax.jit(fn).lower(x).as_text()) == 0
    fn = make_distributed_sort(mesh, "data", cfg=TCFG, engine="argsort",
                               max_attempts=2)
    assert hlo.sort_op_count(jax.jit(fn).lower(x).as_text()) > 0


def test_pallas_custom_call_counter_on_text():
    """The text-side counter recognises hardware custom-call spellings."""
    txt = ('%0 = stablehlo.custom_call @tpu_custom_call(%arg0)\n'
           'ROOT %1 = (f32[8]) custom-call(%0), '
           'custom_call_target="tpu_custom_call"\n')
    assert hlo.pallas_custom_call_count(txt) == 2
    assert hlo.pallas_custom_call_count("stablehlo.sort(%arg0)") == 0