"""Launch-census regression tests: the fused engine's structural guarantee.

The point of ``kernels.fused`` is that one counting pass is ONE Pallas
launch (§4.3–§4.4: partition + scatter + next-pass histogram fused), so the
whole hybrid sort traces to exactly three launch sites — the prologue
histogram, the per-pass fused launch inside the while loop, and the bitonic
local sort — independent of n, the data, and the executed pass count.
``utils.hlo`` counts ``pallas_call`` sites in the jaxpr (interpret mode has
no custom-call in the lowered HLO; on hardware ``pallas_custom_call_count``
covers the lowered text).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import SortConfig, hybrid_sort, lsd_sort, model
from repro.core.outofcore import _sort_chunk, merge_round
from repro.core.segmented import counting_partition
from repro.kernels import merge as kmerge
from repro.kernels.fused import pad_length
from repro.utils import hlo

TCFG = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)


def test_hybrid_fused_engine_one_launch_per_pass():
    """THE acceptance gate: the counting-pass loop body contains exactly one
    pallas_call, and the whole trace exactly three (prologue + pass + local
    sort), for any input size."""
    for n in (257, 4096, 20000):
        jx = jax.make_jaxpr(
            lambda a: hybrid_sort(a, cfg=TCFG, engine="kernel"))(
                jnp.zeros(n, jnp.uint32))
        assert hlo.while_body_pallas_launches(jx) == [1], n
        assert hlo.pallas_launch_count(jx) == 3, n


def test_hybrid_fused_launches_with_values_and_stats():
    x = jnp.zeros(2048, jnp.uint32)
    v = {"a": jnp.zeros(2048, jnp.int32), "b": jnp.zeros(2048, jnp.float32)}
    jx = jax.make_jaxpr(lambda a, b: hybrid_sort(
        a, b, cfg=TCFG, engine="kernel", return_stats=True))(x, v)
    assert hlo.while_body_pallas_launches(jx) == [1]
    assert hlo.pallas_launch_count(jx) == 3


def test_lsd_fused_engine_launch_count():
    """LSD unrolls: ⌈k/d⌉ fused launches + the single prologue histogram."""
    x = jnp.zeros(2048, jnp.uint32)
    for d in (8, 5):
        jx = jax.make_jaxpr(
            lambda a: lsd_sort(a, d=d, engine="kernel", kpb=512))(x)
        assert hlo.pallas_launch_count(jx) == model.num_digits(32, d) + 1, d


def test_counting_partition_fused_launch_count():
    """One standalone partition = prologue histogram + one fused launch."""
    ids = jnp.zeros(1000, jnp.int32)
    jx = jax.make_jaxpr(
        lambda i: counting_partition(i, 8, engine="kernel"))(ids)
    assert hlo.pallas_launch_count(jx) == 2


def test_jnp_engines_launch_free():
    x = jnp.zeros(4096, jnp.uint32)
    for eng in ("argsort", "scan"):
        jx = jax.make_jaxpr(lambda a: hybrid_sort(a, cfg=TCFG, engine=eng))(x)
        assert hlo.pallas_launch_count(jx) == 0, eng


def test_ooc_merge_one_launch_per_round():
    """§5 census: EVERY merge round of an out-of-core sort — whatever the
    run-length mix, group width, or leftover single-run group — is exactly
    one pallas_call, so a full merge phase is ⌈log_K(runs)⌉ launches."""
    tile = 64
    lens = [256] * 8                       # 8 runs, kway=4 -> rounds of 2, 1
    kway = 4
    rounds = 0
    while len(lens) > 1:
        n = sum(lens)
        ck = jnp.zeros((pad_length(n, tile),), jnp.uint32)
        jx = jax.make_jaxpr(
            lambda a, b: merge_round(a, (), b, (), lens=tuple(lens),
                                     kway=kway, tile=tile, n=n,
                                     interpret=True))(ck, jnp.zeros_like(ck))
        census = hlo.launch_census(jx)
        assert census["total"] == 1, lens
        assert not any(census["while_bodies"]), lens
        lens = [sum(g) for g in kmerge.merge_groups(lens, kway)]
        rounds += 1
    assert rounds == kmerge.num_merge_rounds(8, kway) == 2


def test_ooc_chunk_sort_keeps_one_launch_per_pass():
    """The PR 2 invariant under the new driver: an oocsort chunk sort on the
    kernel engine still traces to one launch inside the pass loop, three
    total (prologue + fused pass + local sort)."""
    jx = jax.make_jaxpr(
        lambda a: _sort_chunk(a, (), TCFG, "kernel", True))(
            jnp.zeros(256, jnp.uint32))
    assert hlo.while_body_pallas_launches(jx) == [1]
    assert hlo.pallas_launch_count(jx) == 3
    assert hlo.launch_census(jx) == {"total": 3, "while_bodies": [1]}


def test_pallas_custom_call_counter_on_text():
    """The text-side counter recognises hardware custom-call spellings."""
    txt = ('%0 = stablehlo.custom_call @tpu_custom_call(%arg0)\n'
           'ROOT %1 = (f32[8]) custom-call(%0), '
           'custom_call_target="tpu_custom_call"\n')
    assert hlo.pallas_custom_call_count(txt) == 2
    assert hlo.pallas_custom_call_count("stablehlo.sort(%arg0)") == 0