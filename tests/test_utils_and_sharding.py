"""Unit tests: HLO collective parsing, roofline math, sharding rules,
64-bit key support, gradient compression."""
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo import collective_bytes, collective_counts
from repro.utils.roofline import Roofline, model_flops, PEAK_FLOPS


HLO = """
  %ar = f32[16,4096,2048]{2,1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag = bf16[8,128]{1,0} all-gather(%y), replica_groups=[32,16]<=[512]
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups={{0,1}}
  %a2a = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) all-to-all(%a, %b), replica_groups={{0,1,2,3}}
  %cp = u32[10]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %notacoll = f32[2]{0} add(%p, %q)
"""


def test_collective_bytes_parsing():
    out = collective_bytes(HLO, 512)
    # all-reduce: 16*4096*2048*4 bytes * 2 * 3/4
    assert abs(out["all-reduce"] - 16 * 4096 * 2048 * 4 * 2 * 0.75) < 1
    # all-gather: 8*128*2 * 15/16 (group size 16 from [32,16] form)
    assert abs(out["all-gather"] - 8 * 128 * 2 * 15 / 16) < 1
    # reduce-scatter: out 64*4 * P=2 * 1/2
    assert abs(out["reduce-scatter"] - 64 * 4 * 2 * 0.5) < 1
    # permute: full size
    assert abs(out["collective-permute"] - 40) < 1
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_collective_counts():
    c = collective_counts(HLO)
    assert c == {"all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
                 "all-to-all": 1, "collective-permute": 1}


# async collectives split into -start/-done: the start result is a tuple
# (operand, output, context buffers) — summing it would double-count — and
# replica_groups usually annotates only the start line
HLO_ASYNC = """
  %cps = (u32[10]{0}, u32[10]{0}, u32[], u32[]) collective-permute-start(%c), source_target_pairs={{0,1}}
  %cpd = u32[10]{0} collective-permute-done(%cps)
  %ags = (bf16[8,128]{1,0}, bf16[8,2048]{1,0}) all-gather-start(%y), replica_groups=[32,16]<=[512]
  %agd = bf16[8,2048]{1,0} all-gather-done(%ags)
  %ar = f32[64]{0} all-reduce(%x), replica_groups={{0,1}}
"""


def test_async_collective_pairs_count_once():
    """-start/-done pairs: bytes from the done op's true output shape (at
    the start line's group size), sites counted at the start — one each,
    never zero, never double."""
    out = collective_bytes(HLO_ASYNC, 512)
    assert abs(out["collective-permute"] - 40) < 1          # one hop, 10*u32
    # all-gather output 8*2048*bf16, group size 16 carried from the start
    assert abs(out["all-gather"] - 8 * 2048 * 2 * 15 / 16) < 1
    assert abs(out["all-reduce"] - 64 * 4 * 2 * 0.5) < 1    # sync op intact
    assert out["total"] == sum(v for k, v in out.items() if k != "total")
    assert collective_counts(HLO_ASYNC) == {
        "collective-permute": 1, "all-gather": 1, "all-reduce": 1}


def test_roofline_terms():
    r = Roofline(arch="a", shape="s", step="train", mesh="pod", chips=256,
                 flops_per_chip=197e12, hbm_bytes_per_chip=819e9,
                 coll_bytes_per_chip=50e9, model_flops_global=197e12 * 256,
                 mem_per_chip=8 * 2**30)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 1.0) < 1e-9
    assert r.fits and abs(r.useful_flops_fraction - 1.0) < 1e-9


def test_model_flops_kinds():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config("deepseek_7b")
    n = cfg.active_param_count()
    assert model_flops(cfg, SHAPES["train_4k"]) == 6.0 * n * SHAPES["train_4k"].tokens
    assert model_flops(cfg, SHAPES["decode_32k"]) == 2.0 * n * 128


def test_param_spec_rules():
    from repro.launch.sharding import param_spec
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    from repro.configs import get_config
    cfg = get_config("deepseek_67b")          # 64 heads, fsdp
    mesh = FakeMesh()
    # column-parallel q (stacked layer param)
    assert param_spec("['layers']['attn']['wq']", (95, 8192, 8192), cfg, mesh) \
        == P(None, None, "model")
    # kv heads (8) not divisible by 16 and fsdp fallback on in-dim
    assert param_spec("['layers']['attn']['wk']", (95, 8192, 1024), cfg, mesh) \
        == P(None, "data", None)
    # factored adafactor state for lm_head: rank-1 -> replicate
    assert param_spec("['s']['lm_head']['vr']", (8192,), cfg, mesh) in (P(), P(None))
    # musicgen: 24 heads padded to 32 (head_pad_to) -> attention now shards
    mg = get_config("musicgen_medium")
    assert mg.n_heads_padded == 32 and mg.n_kv_padded == 32
    assert param_spec("['layers']['attn']['wq']", (48, 1536, 2048), mg, mesh) \
        == P(None, None, "model")
    assert param_spec("['layers']['mlp']['w_gate']", (48, 1536, 6144), mg, mesh) \
        == P(None, None, "model")
    # hymba keeps 25 unpadded heads -> attention replicates
    hy = get_config("hymba_1_5b")
    assert param_spec("['layers']['attn']['wq']", (32, 1600, 1600), hy, mesh) \
        == P(None, None, None)


def test_param_spec_normalizes_single_axis_tuples():
    """Every rule branch that shards over the data axes must emit the bare
    axis name on a one-axis data mesh — ``P(None, 'data', None)``, never
    ``P(None, ('data',), None)`` — and keep the real tuple on a pod+data
    mesh.  Covers each fsdp/data branch of param_spec plus the batch/cache
    spec helpers."""
    from repro.launch.sharding import (param_spec, batch_specs, cache_specs,
                                       _norm_axis)
    from repro.configs import get_config
    from jax.sharding import PartitionSpec as P

    class DataMesh:
        shape = {"data": 16, "model": 7}      # model=7: head dims don't divide
        axis_names = ("data", "model")

    class PodMesh:
        shape = {"pod": 2, "data": 8, "model": 7}
        axis_names = ("pod", "data", "model")

    cfg = get_config("deepseek_67b")          # fsdp_params=True, 64/8 heads
    mesh = DataMesh()

    def flat(spec):
        return [ax for ax in spec]

    # wq/wk/wv fsdp fallback (heads % 7 != 0): in-dim shards over data
    for w in ("wq", "wk", "wv"):
        spec = param_spec(f"['layers']['attn']['{w}']", (95, 8192, 1024),
                          cfg, mesh)
        assert spec == P(None, "data", None), w
        assert not any(isinstance(ax, tuple) for ax in flat(spec)), w
    # wo fsdp fallback: out-dim shards over data
    assert param_spec("['layers']['attn']['wo']", (95, 8192, 8192), cfg,
                      mesh) == P(None, None, "data")
    # dense FFN fsdp: the non-f dim shards over data (f dim % 7 != 0)
    assert param_spec("['layers']['mlp']['w_gate']", (95, 8192, 22016), cfg,
                      mesh) == P(None, "data", None)
    assert param_spec("['layers']['mlp']['w_down']", (95, 22016, 8192), cfg,
                      mesh) == P(None, None, "data")
    # MoE expert stacks (E, d, f): fsdp shards d over data
    moe = get_config("qwen3_moe_30b_a3b")
    assert moe.fsdp_params
    assert param_spec("['layers']['moe']['w_gate']", (48, 3, 2048, 768), moe,
                      mesh) == P(None, None, "data", None)
    # batch / cache specs emit the bare name too
    from repro.configs.base import SHAPES
    shape = next(iter(SHAPES.values()))
    tok = batch_specs(cfg, mesh, shape)["tokens"]
    assert not any(isinstance(ax, tuple) for ax in flat(tok))
    cs = cache_specs(cfg, mesh, batch=16, max_len=128)
    assert cs.kv_k is not None
    assert not any(isinstance(ax, tuple) for ax in flat(cs.kv_k))

    # a genuine multi-axis data mesh keeps the ('pod', 'data') tuple
    pod = PodMesh()
    spec = param_spec("['layers']['attn']['wk']", (95, 8192, 1024), cfg, pod)
    assert spec == P(None, ("pod", "data"), None)
    # the helper itself: scalars and multi-tuples pass through, () -> None
    assert _norm_axis("data") == "data"
    assert _norm_axis(("data",)) == "data"
    assert _norm_axis(("pod", "data")) == ("pod", "data")
    assert _norm_axis(()) is None
    assert _norm_axis(None) is None


def test_u64_keys_subprocess():
    """64-bit keys need x64 — isolated in a subprocess."""
    script = textwrap.dedent("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import sys; sys.path.insert(0, "src")
        import numpy as np, jax.numpy as jnp
        from repro.core import hybrid_sort, SortConfig
        rng = np.random.default_rng(0)
        x = rng.integers(0, 2**64, 20000, dtype=np.uint64)
        cfg = SortConfig(d=8, kpb=64, local_threshold=48, merge_threshold=32)
        out, stats = hybrid_sort(jnp.asarray(x), cfg=cfg, return_stats=True)
        assert np.array_equal(np.sort(x), np.asarray(out))
        xf = rng.standard_normal(5000)
        assert np.array_equal(np.sort(xf), np.asarray(hybrid_sort(jnp.asarray(xf), cfg=cfg)))
        print("U64-OK", int(stats.counting_passes))
    """)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert "U64-OK" in res.stdout, res.stdout + res.stderr


@pytest.mark.slow
def test_compressed_psum_subprocess():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum
        from repro.core.distributed import _shard_map   # jax-version shim
        mesh = jax.make_mesh((4,), ("pod",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 256)).astype(np.float32))
        exact = _shard_map(lambda v: jax.lax.psum(v, "pod"), mesh,
                           (P("pod"),), P())(x)
        comp = _shard_map(lambda v: compressed_psum(v, "pod"), mesh,
                          (P("pod"),), P())(x)
        rel = float(jnp.max(jnp.abs(comp - exact)) / jnp.max(jnp.abs(exact)))
        assert rel < 0.05, rel
        print("COMP-OK", rel)
    """)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert "COMP-OK" in res.stdout, res.stdout + res.stderr
