"""Property wall for the distributed sample-sort exchange (§5 analogue).

Two layers:

* In-process simulation — ``_dest_shards`` takes the shard index explicitly,
  so the splitter / routing pipeline runs on the host with no mesh: splitter
  monotonicity, exactly-once routing (the global multiset survives), the
  tie-cycling balance bound on duplicate-heavy inputs, and the ≤ 2x
  clustered-skew regression for the oversampled splitter selection.
  Hypothesis drivers for the same invariants are ``slow``-marked.

* Multi-device subprocess wall (tests/_multidev.py) — byte parity of the
  concatenated valid prefixes against the totalOrder reference for every
  key dtype (uint32 / int32 / float32 incl. NaN and ±0; uint64 under x64),
  KV payloads riding the exchange, and the adversarial overflow-retry
  ledger: a sample-starved splitter set must converge via refinement
  (``exchange_attempts > 1``, no residual overflow) and an infeasible
  capacity must exhaust attempts honestly (residual flag set).
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:  # hypothesis is an optional test dependency (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        @staticmethod
        def lists(*_a, **_k):
            return None

        @staticmethod
        def integers(*_a, **_k):
            return None

from _multidev import run_multidev
from repro.core.distributed import (_dest_shards, _even_sample_ranks,
                                    _select_splitters)
from repro.data.distributions import clustered_keys, zipf_keys

NSHARDS = 8


def _splitters(x: np.ndarray, nshards: int, oversample: int = 64):
    """Host-side replay of the per-shard sample -> global splitter path."""
    shards = [np.sort(s) for s in x.reshape(nshards, -1)]
    chunk = shards[0].shape[0]
    m = max(1, min(nshards * oversample, chunk))
    ranks = np.asarray(_even_sample_ranks(chunk, m))
    gsample = np.sort(np.concatenate([s[ranks] for s in shards]))
    return shards, np.asarray(
        _select_splitters(jnp.asarray(gsample.astype(x.dtype)), nshards))


def _route(x: np.ndarray, nshards: int, oversample: int = 64):
    """(sorted shards, per-shard dests, per-dest loads, max (src,dst) load)."""
    shards, spl = _splitters(x, nshards, oversample)
    dests, loads, pair = [], np.zeros(nshards, np.int64), 0
    for my, s in enumerate(shards):
        d = np.asarray(_dest_shards(jnp.asarray(s), jnp.asarray(spl),
                                    nshards, my))
        dests.append(d)
        c = np.bincount(d, minlength=nshards)
        loads += c
        pair = max(pair, int(c.max()))
    return shards, dests, loads, pair


def _dup_heavy_cases(n):
    rng = np.random.default_rng(3)
    return {
        "all-equal": np.full(n, 7, np.uint32),
        "two-value": rng.choice(np.array([5, 9], np.uint32), n),
        "zipf-1.5": zipf_keys(3, n, a=1.5),
        "clustered": clustered_keys(3, n, clusters=4),
    }


def test_splitters_monotone_deterministic():
    n = NSHARDS * 1900
    cases = _dup_heavy_cases(n)
    cases["uniform"] = np.random.default_rng(0).integers(
        0, 2**32 - 1, n, dtype=np.uint32, endpoint=True)
    for name, x in cases.items():
        _, spl = _splitters(x, NSHARDS)
        assert np.all(np.diff(spl.astype(np.int64)) >= 0), name
        assert spl.shape == (NSHARDS - 1,), name


def test_exactly_once_routing():
    """Every key gets exactly one destination and the global multiset
    survives the route: concatenating the per-destination buckets is a
    permutation of the input."""
    n = NSHARDS * 1900
    for name, x in _dup_heavy_cases(n).items():
        shards, dests, loads, _ = _route(x, NSHARDS)
        assert loads.sum() == n, name       # one dest per key, none dropped
        routed = np.concatenate(
            [s[d == k] for k in range(NSHARDS)
             for s, d in zip(shards, dests)])
        assert np.array_equal(np.sort(routed), np.sort(x)), name


def test_tie_cycling_balance_duplicate_heavy():
    """Duplicate-heavy inputs stay within 2x of ideal: tie cycling spreads
    each splitter-equal run across its whole shard range, so even the
    all-equal (zero-entropy) input balances — and no (source, dest) pair
    exceeds twice its ideal share, which is what the static all_to_all
    capacity (slack = 2.0) relies on."""
    n = NSHARDS * 1900
    chunk = n // NSHARDS
    for name, x in _dup_heavy_cases(n).items():
        _, _, loads, pair = _route(x, NSHARDS)
        assert loads.max() <= 2.0 * (n / NSHARDS), (name, loads)
        assert pair <= 2 * -(-chunk // NSHARDS), (name, pair)


def test_clustered_skew_regression_le_2x():
    """Oversampled even-rank selection keeps clustered-data imbalance ≤ 2x.

    Regression for the ``step::step`` + ``[::stride][:m]`` sampling: floor
    truncation dropped the top ``total % nshards`` sample ranks, so on
    clustered keys with a non-power-of-two shard size every key above the
    last retained rank landed on the final shard (measured 2.3–4.4x ideal).
    """
    for n_local in (1900, 1000):            # non-multiples of the old stride
        for seed in range(2):
            x = clustered_keys(seed, NSHARDS * n_local, clusters=4)
            _, _, loads, _ = _route(x, NSHARDS)
            ideal = x.size / NSHARDS
            assert loads.max() <= 2.0 * ideal, (n_local, seed, loads)

            # the pre-fix sampler on the same input breaches the bound —
            # keeps this regression test honest about what it guards
            shards = [np.sort(s) for s in x.reshape(NSHARDS, -1)]
            m = min(NSHARDS * 64, n_local)
            stride = max(n_local // m, 1)
            g = np.sort(np.concatenate([s[::stride][:m] for s in shards]))
            step = g.shape[0] // NSHARDS
            spl = g[step::step][: NSHARDS - 1]
            loads_old = np.zeros(NSHARDS, np.int64)
            for my, s in enumerate(shards):
                d = np.asarray(_dest_shards(jnp.asarray(s), jnp.asarray(spl),
                                            NSHARDS, my))
                loads_old += np.bincount(d, minlength=NSHARDS)
            assert loads_old.max() > 2.0 * ideal, (n_local, seed, loads_old)


@pytest.mark.slow
@given(st.lists(st.integers(0, 2**32 - 1), min_size=0, max_size=400))
@settings(max_examples=40, deadline=None)
def test_splitters_monotone_hypothesis(vals):
    nshards = 4
    x = np.asarray(vals, np.uint32)
    x = x[: (x.size // nshards) * nshards]
    if x.size == 0:
        return
    _, spl = _splitters(x, nshards, oversample=8)
    assert np.all(np.diff(spl.astype(np.int64)) >= 0)


@pytest.mark.slow
@given(st.lists(st.integers(0, 2**32 - 1), min_size=4, max_size=400))
@settings(max_examples=40, deadline=None)
def test_exactly_once_routing_hypothesis(vals):
    nshards = 4
    x = np.asarray(vals, np.uint32)
    x = x[: (x.size // nshards) * nshards]
    if x.size == 0:
        return
    shards, dests, loads, _ = _route(x, nshards, oversample=8)
    assert loads.sum() == x.size
    routed = np.concatenate(
        [s[d == k] for k in range(nshards) for s, d in zip(shards, dests)])
    assert np.array_equal(np.sort(routed), np.sort(x))


# ---- multi-device subprocess wall -----------------------------------------

# dtype x distribution x payload byte-parity: floats compare against the
# totalOrder reference (ordered-bits round trip — np.sort alone parks every
# NaN last regardless of sign), via bit views so -0.0 == 0.0 cannot hide a
# misplaced zero and NaN payloads stay distinguishable.
PARITY_BODY = """
from repro.core.bijection import from_ordered_bits_np, to_ordered_bits_np
rng = np.random.default_rng(11)
n = NDEV * (1 << 11)
fn = jax.jit(make_distributed_sort(mesh, "data"))
fnkv = jax.jit(make_distributed_sort(mesh, "data", num_chunks=2))

def gen(dtype, dist):
    if dist == "uniform":
        if np.issubdtype(dtype, np.floating):
            x = rng.standard_normal(n).astype(dtype)
        else:
            info = np.iinfo(dtype)
            x = rng.integers(info.min, info.max, n, dtype=dtype,
                             endpoint=True)
    elif dist == "dups":
        x = rng.integers(0, 7, n).astype(dtype)
    else:                                   # "special": float edge cases
        pool = np.array([np.nan, -np.nan, np.inf, -np.inf, 0.0, -0.0,
                         1.5, -1.5], dtype)
        x = pool[rng.integers(0, pool.size, n)]
    return x

def ref_sort(x):
    return from_ordered_bits_np(np.sort(to_ordered_bits_np(x)), x.dtype)

def bits(a):
    return a.view(np.uint32 if a.dtype.itemsize == 4 else np.uint64)

for dtype in (np.uint32, np.int32, np.float32):
    dists = ("uniform", "dups") + (("special",)
                                   if dtype == np.float32 else ())
    for dist in dists:
        x = gen(dtype, dist)
        out, stats = fn(jnp.asarray(x))
        assert not np.asarray(stats.overflow).any(), (dtype, dist)
        got = valid_concat(out, stats.valid)
        assert np.array_equal(bits(got), bits(ref_sort(x))), (dtype, dist)

        v = np.arange(n, dtype=np.int32)
        out, vout, stats = fnkv(jnp.asarray(x), jnp.asarray(v))
        gk = valid_concat(out, stats.valid)
        gv = valid_concat(vout, stats.valid)
        assert np.array_equal(bits(gk), bits(ref_sort(x))), (dtype, dist)
        assert np.array_equal(np.sort(gv), v), (dtype, dist, "exactly once")
        assert np.array_equal(bits(x[gv]), bits(gk)), (dtype, dist, "pairs")
"""

X64_BODY = """
assert jnp.zeros((), jnp.uint64).dtype == jnp.uint64      # x64 active
rng = np.random.default_rng(13)
n = NDEV * (1 << 11)
x = rng.integers(0, 2**64 - 1, n, dtype=np.uint64, endpoint=True)
fn = jax.jit(make_distributed_sort(mesh, "data"))
out, stats = fn(jnp.asarray(x))
assert not np.asarray(stats.overflow).any()
assert np.array_equal(valid_concat(out, stats.valid), np.sort(x))
"""

# adversarial splitter collapse: a 2-per-shard sample cannot see the 95%
# cluster, so attempt 0 overflows at slack 1.2 and the refine=4x re-sample
# converges (measured: the (src,dst) load floor is ~1.11x ideal from
# per-shard binomial variance, so 1.2x capacity is feasible — but only for
# a dense enough sample).  slack 0.5 is infeasible at ANY density: the
# ledger must exhaust attempts and keep the residual overflow flag set.
RETRY_BODY = """
rng = np.random.default_rng(7)
n = NDEV * (1 << 12)
base = rng.integers(0, 2**32 - 1, n, dtype=np.uint32, endpoint=True)
cl = (0x80000000 + rng.integers(0, 1 << 16, n, dtype=np.uint32))
x = np.where(rng.random(n) < 0.95, cl, base).astype(np.uint32)

fn = jax.jit(make_distributed_sort(mesh, "data", oversample=2, slack=1.2,
                                   max_attempts=3))
out, stats = fn(jnp.asarray(x))
attempts = int(np.asarray(stats.exchange_attempts)[0])
assert attempts > 1, attempts                 # retry ledger exercised
assert not np.asarray(stats.overflow).any()   # ...and it converged
assert np.array_equal(valid_concat(out, stats.valid), np.sort(x))

fn = jax.jit(make_distributed_sort(mesh, "data", oversample=2, slack=0.5,
                                   max_attempts=3))
out, stats = fn(jnp.asarray(x))
assert int(np.asarray(stats.exchange_attempts)[0]) == 3
assert np.asarray(stats.overflow).all()       # residual overflow is honest
assert np.asarray(stats.valid).sum() < n      # clipped, not silently "ok"
"""


@pytest.mark.dist
def test_parity_wall_2dev_fast():
    """Fast-tier smoke of the wall at 2 devices (small n, one interpreter)."""
    run_multidev(PARITY_BODY, ndev=2)


@pytest.mark.slow
@pytest.mark.dist
@pytest.mark.parametrize("ndev", [8, 16])
def test_parity_wall_multidev(ndev):
    run_multidev(PARITY_BODY, ndev=ndev)


@pytest.mark.slow
@pytest.mark.dist
def test_parity_uint64_x64():
    run_multidev(X64_BODY, ndev=8, x64=True)


@pytest.mark.slow
@pytest.mark.dist
def test_overflow_retry_adversarial():
    # pinned at 8 devices: the slack/oversample calibration above is
    # width-specific (capacity scales as chunk/nshards)
    run_multidev(RETRY_BODY, ndev=8)
