"""Fault-injection wall: retries, degradation ladder, checkpoint/resume.

The resilience contract of ``core.faults`` + ``core.outofcore``:

  * determinism — the same seed + policy replayed over the same driver
    schedule injects the same faults: two runs produce identical
    ``OocStats`` ledgers and byte-identical output;
  * recovery is invisible in the bytes — transient faults (any site),
    ladder degradations (slab/kway rungs) and detected host corruption all
    end in output byte-identical to the fault-free run, with the clean
    link-byte formulas untouched (failed attempts ledger separately as
    ``retry_link_bytes``);
  * kill-and-resume — a run killed by an injected fatal fault after merge
    round r resumes from its round checkpoint (``resume_from=``) to a
    byte-identical result, with ``rounds_spilled == R - r``, the device
    high-water still under the budget, and the per-round / per-slab-sweep
    launch schedule conserved (asserted through the fault-schedule op
    counters, which count exactly one draw per guarded transfer/launch).
"""
import tempfile

import numpy as np
import pytest
try:  # hypothesis is an optional test dependency (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        @staticmethod
        def integers(*_a, **_k):
            return None

        @staticmethod
        def floats(*_a, **_k):
            return None

from repro.checkpoint import store
from repro.core.faults import (FAULT_SITES, ChecksumError, FatalFault,
                               FaultPolicy, RetriesExhausted, RetryPolicy,
                               host_checksum)
from repro.core.outofcore import oocsort

TILE = 16
BUDGET = 4096
N = 3000
CHUNK = 700


def _data(rng, dtype=np.uint32, n=N):
    if np.dtype(dtype).kind == "f":
        keys = rng.normal(size=n).astype(dtype) * 100.0
    else:
        keys = rng.integers(0, 2 ** 32, n).astype(dtype)
    return keys, np.arange(n, dtype=np.uint32)


def _spill(keys, vals, **kw):
    return oocsort(keys, CHUNK, values=vals, engine="argsort", tile=TILE,
                   spill_budget_bytes=BUDGET, return_stats=True, **kw)


# --------------------------- unit layer --------------------------------------

def test_retry_policy_validation_and_backoff():
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    rp = RetryPolicy(max_retries=3, backoff_base_s=0.01, backoff_cap_s=0.02)
    assert rp.backoff_s(0) == 0.01
    assert rp.backoff_s(5) == 0.02                       # capped
    assert RetryPolicy().backoff_s(9) == 0.0             # base 0: instant


def test_fault_policy_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPolicy(rates={"warp_divergence": 0.5})
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPolicy(fail_at={"bogus": [0]})
    with pytest.raises(ValueError, match="must be in"):
        FaultPolicy(rates={"chunk_upload": 1.5})
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPolicy().draw("bogus")


def test_fault_policy_draws_are_deterministic_and_counted():
    a = FaultPolicy(seed=9, rates={"slab_upload": 0.5})
    b = FaultPolicy(seed=9, rates={"slab_upload": 0.5})
    seq_a = [a.draw("slab_upload") for _ in range(64)]
    seq_b = [b.draw("slab_upload") for _ in range(64)]
    assert seq_a == seq_b                                # pure in (seed, i)
    assert any(k == "transient" for k in seq_a)
    assert any(k is None for k in seq_a)
    assert a.state() == {"slab_upload": 64}
    c = FaultPolicy(seed=9, rates={"slab_upload": 0.5})
    c.load_state({"slab_upload": 32})                    # resume mid-schedule
    assert [c.draw("slab_upload") for _ in range(32)] == seq_a[32:]


def test_host_checksum_detects_flips_and_dtype():
    x = np.arange(64, dtype=np.uint32)
    h = host_checksum(x)
    y = x.copy()
    y.view(np.uint8)[17] ^= 0xFF
    assert host_checksum(y) != h                         # single byte flip
    assert host_checksum(x.view(np.int32)) != h          # dtype mixed in
    assert host_checksum(x.reshape(8, 8)) != h           # shape mixed in


# --------------------------- deterministic replay ----------------------------

def test_deterministic_fault_replay_identical_ledgers_and_bytes(rng):
    keys, vals = _data(rng)
    want_k, want_v, clean = _spill(keys, vals)
    mk = lambda: FaultPolicy(seed=11, rates={"chunk_upload": 0.08,
                                             "slab_upload": 0.08,
                                             "slab_download": 0.08,
                                             "merge_launch": 0.05})
    runs = [_spill(keys, vals, faults=mk(),
                   retry=RetryPolicy(max_retries=6)) for _ in range(2)]
    (k1, v1, s1), (k2, v2, s2) = runs
    assert s1 == s2                                      # identical ledgers
    assert k1.tobytes() == k2.tobytes() == want_k.tobytes()
    assert v1.tobytes() == v2.tobytes() == want_v.tobytes()
    assert s1.faults_injected > 0 and s1.retries > 0
    # retries never bend the clean per-phase formulas
    assert s1.chunk_link_bytes == clean.chunk_link_bytes
    assert s1.spill_link_bytes == clean.spill_link_bytes
    assert s1.h2d_bytes + s1.d2h_bytes == (s1.chunk_link_bytes +
                                           s1.spill_link_bytes +
                                           s1.retry_link_bytes)


@pytest.mark.parametrize("site", [s for s in FAULT_SITES
                                  if s != "host_corruption"])
def test_transient_fault_at_each_site_is_absorbed(rng, site):
    keys, vals = _data(rng)
    want_k, want_v, _ = _spill(keys, vals)
    got_k, got_v, st_ = _spill(keys, vals,
                               faults=FaultPolicy(seed=1,
                                                  fail_at={site: [0, 1]}),
                               retry=RetryPolicy(max_retries=3))
    assert got_k.tobytes() == want_k.tobytes()
    assert got_v.tobytes() == want_v.tobytes()
    assert st_.faults_injected == 2 and st_.retries == 2
    assert st_.degradations == 0                         # retries sufficed
    assert st_.device_high_water_bytes <= BUDGET


def test_fatal_fault_raises_with_ledger(rng):
    keys, vals = _data(rng)
    with pytest.raises(FatalFault) as ei:
        _spill(keys, vals, faults=FaultPolicy(seed=2,
                                              fatal_at={"sort_launch": [1]}))
    assert ei.value.site == "sort_launch"
    assert ei.value.ledger.faults_injected == 1


# --------------------------- degradation ladder ------------------------------

def test_degradation_ladder_slab_kway_rechunk(rng):
    """Three exhaustions walk slab -> kway -> re-chunk; bytes unchanged."""
    keys, vals = _data(rng)
    want_k, want_v, clean = _spill(keys, vals)
    got_k, got_v, st_ = _spill(
        keys, vals,
        faults=FaultPolicy(seed=3, fail_at={"slab_upload": list(range(6))}),
        retry=RetryPolicy(max_retries=1))
    assert st_.degradations == 3
    assert got_k.tobytes() == want_k.tobytes()
    assert got_v.tobytes() == want_v.tobytes()           # unique values: exact
    assert st_.chunk_elems < clean.chunk_elems           # re-chunk rung taken
    assert st_.device_high_water_bytes <= BUDGET         # rungs re-validated


def test_ladder_exhaustion_finally_raises(rng):
    keys = rng.integers(0, 2 ** 32, 64, dtype=np.uint32)
    with pytest.raises(RetriesExhausted):
        oocsort(keys, 16, engine="argsort", tile=TILE,
                spill_budget_bytes=BUDGET,
                faults=FaultPolicy(seed=4,
                                   fail_at={"slab_upload": list(range(500))}),
                retry=RetryPolicy(max_retries=0))


def test_nonspill_kway_degradation_and_parity(rng):
    keys, vals = _data(rng, n=1200)
    want_k, want_v = oocsort(keys, 300, values=vals, engine="argsort",
                             tile=32)
    got_k, got_v, st_ = oocsort(
        keys, 300, values=vals, engine="argsort", tile=32,
        faults=FaultPolicy(seed=5, fail_at={"merge_launch": [0, 1]}),
        retry=RetryPolicy(max_retries=0), return_stats=True)
    assert st_.degradations >= 1                         # kway rung (device)
    assert got_k.tobytes() == want_k.tobytes()
    assert got_v.tobytes() == want_v.tobytes()


# --------------------------- corruption + checkpoint -------------------------

def test_corruption_without_checkpoint_raises(rng):
    keys, vals = _data(rng)
    with pytest.raises(ChecksumError, match="host run"):
        _spill(keys, vals,
               faults=FaultPolicy(seed=6, fail_at={"host_corruption": [1]}))


def test_corruption_recovers_from_round_checkpoint(rng):
    keys, vals = _data(rng)
    want_k, want_v, _ = _spill(keys, vals)
    with tempfile.TemporaryDirectory() as ckpt:
        got_k, got_v, st_ = _spill(
            keys, vals, checkpoint_dir=ckpt,
            faults=FaultPolicy(seed=6, fail_at={"host_corruption": [1]}))
    assert st_.checksum_failures == 1                    # detected + restored
    assert st_.rounds_checkpointed >= 1
    assert got_k.tobytes() == want_k.tobytes()
    assert got_v.tobytes() == want_v.tobytes()


def test_checkpoint_requires_spill_regime(rng):
    keys, vals = _data(rng, n=256)
    with pytest.raises(ValueError, match="host-spill"):
        oocsort(keys, 64, values=vals, checkpoint_dir="/tmp/nope")
    with pytest.raises(ValueError, match="checkpoint_every"):
        oocsort(keys, 64, values=vals, spill_budget_bytes=BUDGET,
                checkpoint_dir="/tmp/nope", checkpoint_every=0)


def test_resume_from_empty_dir_raises():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="no checkpointed rounds"):
            oocsort(None, 0, resume_from=d)


# --------------------------- kill-and-resume (acceptance) --------------------

@pytest.mark.parametrize("dtype,kv,fatal_idx", [
    (np.uint32, True, 0),       # killed at the very first merge launch
    (np.uint32, True, 7),       # killed mid/late merge
    (np.float32, True, 7),      # second dtype, KV payload
    (np.float32, False, 5),     # keys-only
])
def test_kill_and_resume_byte_identical(rng, dtype, kv, fatal_idx):
    keys, vals = _data(rng, dtype=dtype)
    vals = vals if kv else None
    clean_probe = FaultPolicy(seed=0)    # zero-fault: pure launch census
    want = oocsort(keys, CHUNK, values=vals, engine="argsort", tile=TILE,
                   spill_budget_bytes=BUDGET, return_stats=True,
                   faults=clean_probe)
    if kv:
        want_k, want_v, clean = want
    else:
        want_k, clean = want
    census_probe = FaultPolicy(seed=0)   # fresh; counters via manifest
    R = clean.rounds_spilled
    with tempfile.TemporaryDirectory() as ckpt:
        killer = FaultPolicy(seed=7, fatal_at={"merge_launch": [fatal_idx]})
        with pytest.raises(FatalFault):
            oocsort(keys, CHUNK, values=vals, engine="argsort", tile=TILE,
                    spill_budget_bytes=BUDGET, faults=killer,
                    checkpoint_dir=ckpt)
        r = store.latest_step(ckpt)                      # last published round
        assert r is not None and r < R
        out = oocsort(None, 0, resume_from=ckpt, faults=census_probe,
                      spill_budget_bytes=BUDGET, return_stats=True)
        if kv:
            got_k, got_v, st_ = out
            assert got_v.tobytes() == want_v.tobytes()
        else:
            got_k, st_ = out
    assert got_k.tobytes() == want_k.tobytes()           # byte-identical
    assert st_.rounds_spilled == R - r                   # replay from round r
    assert st_.device_high_water_bytes <= BUDGET
    assert st_.faults_injected == 0 and st_.degradations == 0
    # launch-census conservation: the manifest carries the killed run's op
    # counters as-of round r, the resumed schedule draws the remainder — one
    # draw per slab upload / merge launch / slab download — so the resumed
    # probe's totals land exactly on the uninterrupted run's census: the
    # per-round / per-slab-sweep launch schedule is unchanged by kill+resume.
    clean_counts, got_counts = clean_probe.state(), census_probe.state()
    for site in ("slab_upload", "merge_launch", "slab_download"):
        assert got_counts.get(site, 0) == clean_counts.get(site, 0), site


def test_resume_into_new_checkpoint_dir_continues_publishing(rng):
    keys, vals = _data(rng)
    want_k, want_v, _ = _spill(keys, vals)
    with tempfile.TemporaryDirectory() as a, tempfile.TemporaryDirectory() as b:
        with pytest.raises(FatalFault):
            _spill(keys, vals, checkpoint_dir=a,
                   faults=FaultPolicy(seed=8,
                                      fatal_at={"merge_launch": [3]}))
        got_k, got_v = oocsort(None, 0, resume_from=a, checkpoint_dir=b)
        assert store.latest_step(b) is not None          # re-published
    assert got_k.tobytes() == want_k.tobytes()
    assert got_v.tobytes() == want_v.tobytes()


def test_resume_values_like_restores_structure(rng):
    keys, vals = _data(rng)
    with tempfile.TemporaryDirectory() as ckpt:
        with pytest.raises(FatalFault):
            oocsort(keys, CHUNK, values={"idx": vals}, engine="argsort",
                    tile=TILE, spill_budget_bytes=BUDGET,
                    checkpoint_dir=ckpt,
                    faults=FaultPolicy(seed=9,
                                       fatal_at={"merge_launch": [2]}))
        got_k, got_v = oocsort(None, 0, resume_from=ckpt,
                               values_like={"idx": np.empty(0, np.uint32)})
        assert set(got_v) == {"idx"}
        with pytest.raises(ValueError, match="leaves"):
            oocsort(None, 0, resume_from=ckpt,
                    values_like=(np.empty(0), np.empty(0)))


# --------------------------- fault storm (slow wall) -------------------------

@pytest.mark.slow
@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2 ** 16),
       rate=st.floats(0.01, 0.12))
def test_fault_storm_byte_parity(seed, rate):
    """Random faults at every transfer/launch site: bytes never change."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2 ** 32, 2000, dtype=np.uint32)
    vals = np.arange(2000, dtype=np.uint32)
    want_k, want_v = oocsort(keys, 600, values=vals, engine="argsort",
                             tile=TILE, spill_budget_bytes=BUDGET)
    sites = [s for s in FAULT_SITES if s != "host_corruption"]
    got_k, got_v, st_ = oocsort(
        keys, 600, values=vals, engine="argsort", tile=TILE,
        spill_budget_bytes=BUDGET,
        faults=FaultPolicy(seed=seed, rates={s: rate for s in sites}),
        retry=RetryPolicy(max_retries=16), return_stats=True)
    assert got_k.tobytes() == want_k.tobytes()
    assert got_v.tobytes() == want_v.tobytes()
    assert st_.degradations == 0                 # 16 retries absorb any burst
    assert st_.h2d_bytes + st_.d2h_bytes == (st_.chunk_link_bytes +
                                             st_.spill_link_bytes +
                                             st_.retry_link_bytes)
